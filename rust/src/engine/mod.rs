//! # PolicyEngine — the unified MPQ search API
//!
//! The paper's deployment story (§4.3) makes policy search the
//! production hot path: once importances are learned, every device
//! constraint is answered by a sub-second data-free solve.  This module
//! is the one front door to that path:
//!
//! * [`Solver`] — trait over every solver family (`bb`, `mckp`,
//!   `lp-round`, `pareto`, `greedy`), each reporting effort and bound
//!   telemetry through [`SolveOutcome`];
//! * [`SearchRequest`] — a validated builder replacing the positional
//!   sprawl of `MpqProblem::from_importance` + `solve`;
//! * [`SolverRegistry`] — named lookup plus an automatic fallback chain
//!   (exact B&B → MCKP DP → LP-guided rounding → Pareto → greedy);
//! * [`PolicyEngine`] — the memoizing fleet front-end: model + learned
//!   importances + registry + an LRU policy cache keyed on
//!   canonicalized requests, so repeated fleet/device queries are O(1).
//!
//! Every consumer (fleet server, CLI, coordinator, experiment drivers,
//! benches) goes through this module; `search::` keeps only the raw
//! problem substrate and algorithms.
//!
//! Problems are **group-based**: at the default
//! [`Granularity::Layer`](crate::search::Granularity) each group is one
//! layer (the paper's setting, bit-for-bit the pre-group engine), while
//! `channel:<g>` / `kernel` requests split every unpinned layer into
//! channel groups, multiplying the variable count by ~2 orders of
//! magnitude.  The registry keeps such instances tractable with an MCKP
//! dominance-pruning pass (options pointwise no better than a sibling
//! are dropped before any solver runs; the count lands in
//! [`SolveStats::pruned`]) and by reordering the Auto chain so the
//! decomposed, pool-parallel `lp-round` runs before exact B&B.
//!
//! ```no_run
//! # use limpq::engine::{PolicyEngine, SearchRequest};
//! # fn demo(meta: limpq::models::ModelMeta, imp: limpq::importance::Importance) -> anyhow::Result<()> {
//! let engine = PolicyEngine::new(meta, imp);
//! let req = SearchRequest::builder().alpha(3.0).bitops_cap(23_070_000_000).build()?;
//! let resp = engine.solve(&req)?;            // cold: runs the registry
//! let again = engine.solve(&req)?;           // hot: LRU cache, O(1)
//! assert!(again.cache_hit);
//! assert_eq!(resp.outcome.policy, again.outcome.policy);
//! # Ok(()) }
//! ```

pub mod cache;
pub mod request;
pub mod solvers;

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

pub use self::request::{
    CancelToken, CanonicalKey, SearchRequest, SearchRequestBuilder, SolveBudget, SolverPref,
};
pub use self::solvers::{
    BranchAndBound, GreedyRepair, MckpDp, ParetoFrontier, SimplexRelax, SolveOutcome, Solver,
};

use self::cache::LruCache;
use crate::importance::Importance;
use crate::models::ModelMeta;
use crate::quant::BitConfig;
use crate::search::{MpqProblem, Solution};

/// Telemetry for one engine solve.
#[derive(Debug, Clone)]
pub struct SolveStats {
    /// The solver that produced the solution (after any fallback).
    pub solver: String,
    /// ILP variable count of the solved problem (total options).
    pub n_vars: usize,
    /// Solver-native effort units (B&B nodes, DP cell relaxations).
    pub nodes: u64,
    /// `cost − lower_bound` when the solver certified a bound.
    pub bound_gap: Option<f64>,
    pub proven_optimal: bool,
    /// Wall time of the winning solver's run.
    pub wall_us: u128,
    /// How many solvers failed before one succeeded (Auto mode).
    pub fallbacks: u32,
    /// Options removed by the registry's dominance preprocessing before
    /// the winning solver ran (0 when the pass was skipped — layer-sized
    /// instances — or nothing was dominated).
    pub pruned: usize,
    /// True when this outcome came from the degradation chain (deadline
    /// expiry, solver panic, or breaker shed) rather than a clean solve.
    /// Degraded outcomes are never cached.
    pub degraded: bool,
    /// Why the outcome is degraded, when it is.  Panic-caused reasons
    /// start with [`PANIC_REASON`].
    pub degraded_reason: Option<String>,
}

/// Degradation-reason prefix for solver panics.  The fleet dispatcher's
/// per-model circuit breaker string-matches this prefix to count real
/// solver faults; honest solve failures (infeasible caps, unknown
/// solver names) never carry it and so can never trip the breaker.
pub const PANIC_REASON: &str = "solver panicked";

/// A solved policy plus everything a caller may want to report.
#[derive(Debug, Clone)]
pub struct PolicyOutcome {
    pub policy: BitConfig,
    pub solution: Solution,
    pub stats: SolveStats,
}

/// What [`PolicyEngine::solve`] returns: the (possibly shared) outcome
/// and whether this particular call was served from the policy cache.
#[derive(Debug, Clone)]
pub struct EngineResponse {
    pub outcome: Arc<PolicyOutcome>,
    pub cache_hit: bool,
}

/// Cache counters for operator dashboards (`limpq serve`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
    pub entries: usize,
    pub capacity: usize,
    /// Calls that blocked on another caller's in-progress identical solve
    /// (single-flight followers).  Each also counts as a hit.
    pub inflight_waits: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Ordered solver registry with named lookup and automatic fallback.
pub struct SolverRegistry {
    solvers: Vec<Arc<dyn Solver>>,
}

impl SolverRegistry {
    /// The standard chain: exact first, heuristics as last resorts.
    pub fn standard() -> SolverRegistry {
        SolverRegistry {
            solvers: vec![
                Arc::new(BranchAndBound),
                Arc::new(MckpDp),
                Arc::new(SimplexRelax),
                Arc::new(ParetoFrontier),
                Arc::new(GreedyRepair),
            ],
        }
    }

    /// A registry with a custom chain (tests, experiments).
    pub fn with_solvers(solvers: Vec<Arc<dyn Solver>>) -> SolverRegistry {
        SolverRegistry { solvers }
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.solvers.iter().map(|s| s.name()).collect()
    }

    pub fn get(&self, name: &str) -> Option<Arc<dyn Solver>> {
        self.solvers.iter().find(|s| s.name() == name).cloned()
    }

    /// Solve a raw problem honoring the preference: `Named` runs exactly
    /// that solver; `Auto` walks the chain, skipping solvers that do not
    /// support the constraint shape and falling back past failures.
    pub fn solve(
        &self,
        p: &MpqProblem,
        pref: &SolverPref,
        budget: &SolveBudget,
    ) -> Result<(Solution, SolveStats)> {
        // Defense in depth for hand-built requests: Named("auto") means
        // the fallback chain, never a lookup (build() also normalizes).
        let auto = SolverPref::Auto;
        let pref = match pref {
            SolverPref::Named(n) if n == "auto" || n.is_empty() => &auto,
            other => other,
        };
        // Dominance preprocessing for fine-grained instances: options
        // pointwise no better than a sibling cannot appear in any optimal
        // solution (`search::prune_dominated`), so every solver sees the
        // reduced instance and choices are mapped back afterwards.
        // Layer-sized instances skip the pass entirely — their solves
        // stay byte-identical to the pre-group engine.
        let fine = p.n_vars() > crate::search::FINE_GRAIN_VARS;
        let pruned = if fine { Some(crate::search::prune_dominated(p)) } else { None };
        let (sp, dropped) = match &pruned {
            Some(pr) => (&pr.problem, pr.dropped),
            None => (p, 0),
        };
        let restore = |s: &Solution| match &pruned {
            Some(pr) => pr.restore(s),
            None => s.clone(),
        };
        match pref {
            SolverPref::Named(name) => {
                let Some(s) = self.get(name) else {
                    bail!("unknown solver {name:?} (registered: {})", self.names().join(", "));
                };
                if !s.supports(sp) {
                    bail!(
                        "solver {name:?} does not support this problem's constraint shape \
                         (bitops cap: {}, size cap: {})",
                        p.bitops_cap.is_some(),
                        p.size_cap_bits.is_some()
                    );
                }
                let t = Instant::now();
                let mut out = s.solve_full(sp, budget)?;
                out.pruned = dropped;
                let solution = restore(&out.solution);
                Ok((solution, stats_of(s.name(), p.n_vars(), &out, t, 0)))
            }
            SolverPref::Auto => {
                let mut failures: Vec<String> = Vec::new();
                // Fine-grained instances flip the chain head: the
                // decomposed `lp-round` answers 10k+ variables inside the
                // default budget, while exact B&B would burn its whole
                // node budget before falling through.
                let order: Vec<&Arc<dyn Solver>> = if fine {
                    let mut v: Vec<&Arc<dyn Solver>> =
                        self.solvers.iter().filter(|s| s.name() == "lp-round").collect();
                    v.extend(self.solvers.iter().filter(|s| s.name() != "lp-round"));
                    v
                } else {
                    self.solvers.iter().collect()
                };
                for s in order {
                    if !s.supports(sp) {
                        continue;
                    }
                    let t = Instant::now();
                    match s.solve_full(sp, budget) {
                        Ok(mut out) => {
                            out.pruned = dropped;
                            let stats =
                                stats_of(s.name(), p.n_vars(), &out, t, failures.len() as u32);
                            let solution = restore(&out.solution);
                            return Ok((solution, stats));
                        }
                        Err(e) => failures.push(format!("{}: {e:#}", s.name())),
                    }
                }
                bail!("every solver failed — {}", failures.join("; "))
            }
        }
    }
}

fn stats_of(
    name: &str,
    n_vars: usize,
    out: &SolveOutcome,
    started: Instant,
    fallbacks: u32,
) -> SolveStats {
    SolveStats {
        solver: name.to_string(),
        n_vars,
        nodes: out.nodes,
        bound_gap: out.lower_bound.map(|lb| out.solution.cost - lb),
        proven_optimal: out.proven_optimal,
        wall_us: started.elapsed().as_micros(),
        fallbacks,
        pruned: out.pruned,
        degraded: out.cancelled,
        degraded_reason: out
            .cancelled
            .then(|| "cancelled mid-search (deadline or shed); best incumbent returned".to_string()),
    }
}

/// Process-wide standard registry (solvers are stateless).
pub fn standard_registry() -> &'static SolverRegistry {
    static REG: OnceLock<SolverRegistry> = OnceLock::new();
    REG.get_or_init(SolverRegistry::standard)
}

/// Solve a raw [`MpqProblem`] through the standard registry — the
/// replacement for the old `search::solve()` free function wherever a
/// problem is built by hand (Hessian baselines, synthetic benches).
pub fn solve_problem(
    p: &MpqProblem,
    pref: &SolverPref,
    budget: &SolveBudget,
) -> Result<(Solution, SolveStats)> {
    standard_registry().solve(p, pref, budget)
}

/// Shorthand: solve a raw problem with the default chain and budget.
pub fn solve_auto(p: &MpqProblem) -> Result<Solution> {
    solve_problem(p, &SolverPref::Auto, &SolveBudget::default()).map(|(s, _)| s)
}

// ---------------------------------------------------------------------------
// PolicyEngine
// ---------------------------------------------------------------------------

/// Default LRU capacity for the policy cache (also the registry's
/// per-model default, see [`crate::registry::RegistryConfig`]).
pub const DEFAULT_CACHE_CAPACITY: usize = 512;

/// Upper bound on one single-flight follower condvar wait: a follower
/// re-checks its own [`CancelToken`] at least this often, so an explicit
/// cancel (which has no deadline to time the wait against) is observed
/// promptly even if the leader never publishes.
const FOLLOWER_RECHECK: Duration = Duration::from_millis(25);

/// A solve in progress: followers block on `cv` until the leader fills
/// `done` (the outcome, or the error rendered to a string — `anyhow`
/// errors are not cloneable).
struct InflightSolve {
    done: Mutex<Option<std::result::Result<Arc<PolicyOutcome>, String>>>,
    cv: Condvar,
}

/// Publishes the leader's result to followers and clears the in-flight
/// registration — on every exit path, including a panicking solver (the
/// `Drop` arm), so a follower can never block forever.
struct SingleFlightGuard<'a> {
    engine: &'a PolicyEngine,
    key: &'a CanonicalKey,
    slot: &'a Arc<InflightSolve>,
    published: bool,
}

impl SingleFlightGuard<'_> {
    fn publish(&mut self, r: std::result::Result<Arc<PolicyOutcome>, String>) {
        if self.published {
            return;
        }
        self.published = true;
        // Order matters: complete the slot *before* unregistering it, so
        // a racing request either finds the completed slot (returns
        // immediately) or finds nothing and hits the now-populated cache.
        *self.slot.done.lock().unwrap() = Some(r);
        self.slot.cv.notify_all();
        self.engine.inflight.lock().unwrap().remove(self.key);
    }
}

impl Drop for SingleFlightGuard<'_> {
    fn drop(&mut self) {
        if !self.published {
            self.publish(Err("solver panicked mid-solve".into()));
        }
    }
}

/// The memoizing search front-end: owns the model meta and the one-time
/// learned importances, builds eq.-3 problems from [`SearchRequest`]s,
/// solves through the registry, and caches outcomes by canonical key.
/// Shareable across threads (`Arc<PolicyEngine>`): the cache sits behind
/// a mutex that is never held during a solve, and concurrent identical
/// cold requests are **single-flighted** — one leader runs the solver,
/// every follower blocks on the same in-flight slot and shares the
/// outcome, so a fleet stampede costs exactly one solve.  A follower
/// still answers to its *own* [`CancelToken`]: if its deadline fires
/// before the leader publishes, it leaves the wait and degrades under
/// its own supervision rather than inheriting the leader's.
pub struct PolicyEngine {
    pub meta: Arc<ModelMeta>,
    pub importance: Arc<Importance>,
    registry: &'static SolverRegistry,
    policy_cache: Mutex<LruCache<CanonicalKey, Arc<PolicyOutcome>>>,
    inflight: Mutex<HashMap<CanonicalKey, Arc<InflightSolve>>>,
    /// Most recent clean (non-degraded) outcome — the degradation chain's
    /// last resort when even the direct greedy fallback cannot answer.
    last_good: Mutex<Option<Arc<PolicyOutcome>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    inflight_waits: AtomicUsize,
}

impl PolicyEngine {
    pub fn new(meta: ModelMeta, importance: Importance) -> PolicyEngine {
        Self::with_cache_capacity(meta, importance, DEFAULT_CACHE_CAPACITY)
    }

    pub fn with_cache_capacity(
        meta: ModelMeta,
        importance: Importance,
        capacity: usize,
    ) -> PolicyEngine {
        Self::with_registry(meta, importance, capacity, standard_registry())
    }

    /// Engine over a custom registry (tests inject slow/failing solvers
    /// to pin down the single-flight protocol).
    pub fn with_registry(
        meta: ModelMeta,
        importance: Importance,
        capacity: usize,
        registry: &'static SolverRegistry,
    ) -> PolicyEngine {
        PolicyEngine {
            meta: Arc::new(meta),
            importance: Arc::new(importance),
            registry,
            policy_cache: Mutex::new(LruCache::new(capacity)),
            inflight: Mutex::new(HashMap::new()),
            last_good: Mutex::new(None),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            inflight_waits: AtomicUsize::new(0),
        }
    }

    /// Materialize the eq.-3 MCKP instance for a request.
    pub fn problem(&self, req: &SearchRequest) -> MpqProblem {
        MpqProblem::from_importance(
            &self.meta,
            &self.importance,
            req.alpha,
            req.bitops_cap,
            req.size_cap_bits,
            req.weight_only,
            req.granularity,
        )
    }

    /// Memoized solve: identical canonical requests after the first are
    /// served from the LRU cache in O(1) without touching a solver, and
    /// identical requests arriving *while* the first is still solving
    /// block on that one solve (single-flight) instead of racing it —
    /// exactly one solver run per distinct cold key, stampede or not.
    pub fn solve(&self, req: &SearchRequest) -> Result<EngineResponse> {
        let key = req.canonical_key();
        if let Some(outcome) = self.policy_cache.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(EngineResponse { outcome, cache_hit: true });
        }
        // Register as leader or join an in-flight solve as follower.
        let (slot, leader) = {
            let mut inflight = self.inflight.lock().unwrap();
            match inflight.get(&key) {
                Some(slot) => (slot.clone(), false),
                None => {
                    // Double-check the cache under the in-flight lock: a
                    // leader that finished between our miss above and this
                    // lock has already unregistered and populated the
                    // cache, and must not be re-solved.
                    if let Some(outcome) = self.policy_cache.lock().unwrap().get(&key) {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        return Ok(EngineResponse { outcome, cache_hit: true });
                    }
                    let slot = Arc::new(InflightSolve {
                        done: Mutex::new(None),
                        cv: Condvar::new(),
                    });
                    inflight.insert(key.clone(), slot.clone());
                    (slot, true)
                }
            }
        };
        if !leader {
            self.inflight_waits.fetch_add(1, Ordering::Relaxed);
            // Wait under the follower's *own* token, not the leader's:
            // the leader may have a laxer deadline (or none), and this
            // request's end-to-end supervision must still hold.  The wait
            // is chunked so an explicitly cancelled flag is observed too.
            let cancel = &req.budget.cancel;
            let mut done = slot.done.lock().unwrap();
            while done.is_none() {
                if cancel.expired() {
                    drop(done);
                    let reason = "deadline expired waiting on an in-flight identical solve";
                    return match self.fallback_outcome(req, reason) {
                        Some(outcome) => {
                            Ok(EngineResponse { outcome: Arc::new(outcome), cache_hit: false })
                        }
                        None => Err(anyhow::anyhow!(
                            "{reason}, and no degraded fallback is available"
                        )),
                    };
                }
                let wait = cancel.deadline().map_or(FOLLOWER_RECHECK, |d| {
                    d.saturating_duration_since(Instant::now()).min(FOLLOWER_RECHECK)
                });
                let (guard, _) = slot.cv.wait_timeout(done, wait).unwrap();
                done = guard;
            }
            return match done.as_ref().unwrap() {
                Ok(outcome) => {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    Ok(EngineResponse { outcome: outcome.clone(), cache_hit: true })
                }
                Err(msg) => Err(anyhow::anyhow!("single-flight leader failed: {msg}")),
            };
        }
        // Leader: solve without holding any lock; the guard publishes the
        // result (or the panic) to followers on every exit path.  A fault
        // (panic, deadline expiry) walks the degradation chain instead of
        // erroring, so followers receive a usable degraded outcome.
        let mut guard = SingleFlightGuard { engine: self, key: &key, slot: &slot, published: false };
        let outcome = match self.solve_attempt(req) {
            Ok(outcome) => outcome,
            Err(e) => {
                let msg = format!("{e:#}");
                let panicked = msg.starts_with(PANIC_REASON);
                if !panicked && !req.budget.cancel.expired() {
                    // An honest solve failure (infeasible cap, unknown
                    // solver): there is nothing truthful to degrade to.
                    guard.publish(Err(msg));
                    return Err(e);
                }
                let reason = if panicked { msg.clone() } else { "deadline expired".to_string() };
                match self.fallback_outcome(req, &reason) {
                    Some(outcome) => outcome,
                    None => {
                        guard.publish(Err(msg));
                        return Err(e);
                    }
                }
            }
        };
        let outcome = Arc::new(outcome);
        self.misses.fetch_add(1, Ordering::Relaxed);
        if !outcome.stats.degraded {
            // Degraded answers are never cached (a retry once the fault
            // clears must reach a real solver) and never become last_good.
            self.policy_cache.lock().unwrap().insert(key.clone(), outcome.clone());
            *self.last_good.lock().unwrap() = Some(outcome.clone());
        }
        guard.publish(Ok(outcome.clone()));
        Ok(EngineResponse { outcome, cache_hit: false })
    }

    /// One registry run under a panic firewall: a panicking solver
    /// becomes an `Err` whose message starts with [`PANIC_REASON`], so
    /// callers (and the dispatcher's circuit breaker) can tell real
    /// solver faults from honest solve failures.
    fn solve_attempt(&self, req: &SearchRequest) -> Result<PolicyOutcome> {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.solve_uncached(req))) {
            Ok(r) => r,
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "opaque panic payload".to_string());
                Err(anyhow::anyhow!("{PANIC_REASON}: {msg}"))
            }
        }
    }

    /// The degradation chain below the solver's own incumbent: a direct
    /// greedy construction (bypassing the registry, so it is available
    /// even when the registry chain is broken), then the last clean
    /// outcome for this model — stale, but the right shape, and only if
    /// it satisfies **this** request's caps.  `None` when neither
    /// applies; the caller then reports the original error.
    fn fallback_outcome(&self, req: &SearchRequest, reason: &str) -> Option<PolicyOutcome> {
        let p = self.problem(req);
        // Greedy has no cancellation points and runs in microseconds, so
        // it is safe to invoke after the request's token already fired.
        let t = Instant::now();
        if GreedyRepair.supports(&p) {
            if let Ok(out) = GreedyRepair.solve_full(&p, &SolveBudget::default()) {
                let mut stats = stats_of("greedy", p.n_vars(), &out, t, 0);
                stats.degraded = true;
                stats.degraded_reason = Some(reason.to_string());
                let policy = p.to_bit_config(&out.solution);
                return Some(PolicyOutcome { policy, solution: out.solution, stats });
            }
        }
        let last = self.last_good.lock().unwrap().clone()?;
        // The stale policy was solved under *different* constraints: if it
        // blows this request's bitops/size caps, serving it with ok:true
        // would hand the client a policy its hardware budget cannot hold.
        // Refuse and let the caller report the original error instead.
        let fits = req.bitops_cap.map_or(true, |c| last.solution.bitops <= c)
            && req.size_cap_bits.map_or(true, |c| last.solution.size_bits <= c);
        if !fits {
            return None;
        }
        let mut outcome = (*last).clone();
        outcome.stats.degraded = true;
        outcome.stats.degraded_reason = Some(format!("{reason}; serving last good policy"));
        Some(outcome)
    }

    /// Answer without touching the registry — used by the fleet's circuit
    /// breaker to shed load while a model's solvers are misbehaving.  A
    /// cached clean answer still wins (shedding must not hide it); only a
    /// cold request pays the degradation chain.
    pub fn solve_degraded(&self, req: &SearchRequest, reason: &str) -> Result<EngineResponse> {
        let key = req.canonical_key();
        if let Some(outcome) = self.policy_cache.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(EngineResponse { outcome, cache_hit: true });
        }
        match self.fallback_outcome(req, reason) {
            Some(outcome) => {
                Ok(EngineResponse { outcome: Arc::new(outcome), cache_hit: false })
            }
            None => bail!("degraded fallback unavailable ({reason}) and no last good policy"),
        }
    }

    /// Always run the solver (bench cold paths, accuracy measurements).
    pub fn solve_uncached(&self, req: &SearchRequest) -> Result<PolicyOutcome> {
        let p = self.problem(req);
        let (solution, stats) = self.registry.solve(&p, &req.solver, &req.budget)?;
        Ok(PolicyOutcome { policy: p.to_bit_config(&solution), solution, stats })
    }

    pub fn cache_stats(&self) -> CacheStats {
        let cache = self.policy_cache.lock().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: cache.len(),
            capacity: cache.capacity(),
            inflight_waits: self.inflight_waits.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::importance::IndicatorStore;
    use crate::quant::cost::uniform_bitops;
    use crate::search::testutil::random_problem;
    use crate::util::rng::Rng;

    fn meta6() -> ModelMeta {
        crate::models::synthetic_meta(6, |i| 100_000 * (i as u64 + 1))
    }

    fn engine() -> PolicyEngine {
        let meta = meta6();
        let imp = IndicatorStore::init_uniform(&meta).importance(&meta);
        PolicyEngine::new(meta, imp)
    }

    #[test]
    fn second_identical_request_is_a_cache_hit_with_identical_policy() {
        let e = engine();
        let cap = uniform_bitops(&e.meta, 4, 4);
        let req = SearchRequest::builder().alpha(2.0).bitops_cap(cap).build().unwrap();
        let first = e.solve(&req).unwrap();
        assert!(!first.cache_hit);
        let second = e.solve(&req).unwrap();
        assert!(second.cache_hit);
        assert_eq!(first.outcome.policy, second.outcome.policy);
        assert_eq!(first.outcome.solution, second.outcome.solution);
        let stats = e.cache_stats();
        assert_eq!((stats.hits, stats.misses, stats.entries), (1, 1, 1));
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);

        // A separately built but canonically equal request also hits.
        let rebuilt = SearchRequest::builder().alpha(2.0).bitops_cap(cap).build().unwrap();
        assert!(e.solve(&rebuilt).unwrap().cache_hit);
        // A different constraint misses.
        let other = SearchRequest::builder().alpha(2.0).bitops_cap(cap + 1).build().unwrap();
        assert!(!e.solve(&other).unwrap().cache_hit);
    }

    #[test]
    fn named_solver_runs_and_reports_itself() {
        let e = engine();
        let cap = uniform_bitops(&e.meta, 4, 4);
        for name in ["bb", "mckp", "lp-round", "pareto", "greedy"] {
            let req = SearchRequest::builder()
                .bitops_cap(cap)
                .solver_name(name)
                .build()
                .unwrap();
            match e.solve_uncached(&req) {
                Ok(out) => {
                    assert_eq!(out.stats.solver, name);
                    assert!(out.solution.bitops <= cap);
                }
                // frontier heuristics may miss on some shapes; exacts may not
                Err(e) => assert!(
                    matches!(name, "pareto" | "lp-round"),
                    "{name} should not fail: {e:#}"
                ),
            }
        }
    }

    #[test]
    fn named_unknown_solver_is_an_error() {
        let e = engine();
        let req = SearchRequest::builder()
            .bitops_cap(1 << 40)
            .solver_name("cplex")
            .build()
            .unwrap();
        let err = e.solve(&req).unwrap_err();
        assert!(format!("{err:#}").contains("unknown solver"), "{err:#}");
    }

    #[test]
    fn named_mckp_rejects_two_constraint_requests() {
        let e = engine();
        let cap = uniform_bitops(&e.meta, 4, 4);
        let req = SearchRequest::builder()
            .bitops_cap(cap)
            .size_cap_bits(1 << 40)
            .solver_name("mckp")
            .build()
            .unwrap();
        assert!(e.solve(&req).is_err());
        // Auto handles the same shape via branch-and-bound.
        let auto = SearchRequest::builder()
            .bitops_cap(cap)
            .size_cap_bits(1 << 40)
            .build()
            .unwrap();
        let out = e.solve(&auto).unwrap();
        assert_eq!(out.outcome.stats.solver, "bb");
        assert!(out.outcome.stats.proven_optimal);
    }

    #[test]
    fn auto_falls_through_unsupported_solvers() {
        // Custom registry of [mckp, greedy] only: a two-constraint
        // problem skips mckp (unsupported shape) and falls through to
        // greedy, which must then produce the answer.
        let reg = SolverRegistry::with_solvers(vec![
            Arc::new(MckpDp),
            Arc::new(GreedyRepair),
        ]);
        let mut rng = Rng::new(31);
        let mut p = random_problem(&mut rng, 4, 3, 0.7);
        let min_s: u64 =
            p.groups.iter().map(|o| o.iter().map(|x| x.size_bits).min().unwrap()).sum();
        let max_s: u64 =
            p.groups.iter().map(|o| o.iter().map(|x| x.size_bits).max().unwrap()).sum();
        p.size_cap_bits = Some(min_s + (max_s - min_s) * 8 / 10);
        let (sol, stats) = reg.solve(&p, &SolverPref::Auto, &SolveBudget::default()).unwrap();
        assert_eq!(stats.solver, "greedy");
        assert!(p.feasible(&sol));
    }

    /// Satellite property: MCKP dominance pruning never changes the
    /// optimum.  Exact solvers must return the same cost on the pruned
    /// instance as on the original, and choices restored through the
    /// keep-lists must evaluate cleanly on the original problem.
    #[test]
    fn dominance_pruning_preserves_every_solvers_optimum() {
        let mut rng = Rng::new(0xD011);
        for trial in 0..25 {
            let layers = 2 + rng.below(4);
            let opts = 2 + rng.below(4);
            let tight = rng.uniform(0.2, 0.9);
            let p = random_problem(&mut rng, layers, opts, tight);
            let pr = crate::search::prune_dominated(&p);
            let budget = SolveBudget {
                dp_grid: p.bitops_cap.unwrap() as usize + 1,
                ..SolveBudget::default()
            };
            for name in ["bb", "mckp", "lp-round", "pareto", "greedy"] {
                let s = standard_registry().get(name).unwrap();
                if !s.supports(&p) {
                    continue;
                }
                let orig = s.solve_full(&p, &budget);
                let reduced = s.solve_full(&pr.problem, &budget);
                match (orig, reduced) {
                    (Ok(a), Ok(b)) => {
                        let restored = pr.restore(&b.solution);
                        let re = p.evaluate(&restored.choice).unwrap();
                        assert!(p.feasible(&re), "trial {trial}: {name} restored infeasible");
                        assert!(
                            (re.cost - b.solution.cost).abs() < 1e-9,
                            "trial {trial}: {name} restore changed cost"
                        );
                        // Exact solvers must be unaffected by pruning.
                        if matches!(name, "bb" | "mckp") {
                            assert!(
                                (a.solution.cost - b.solution.cost).abs() < 1e-9,
                                "trial {trial}: {name} optimum moved ({} vs {})",
                                a.solution.cost,
                                b.solution.cost
                            );
                        }
                    }
                    // Heuristics may miss on either instance; exact
                    // solvers must agree on feasibility.
                    (Err(_), Err(_)) => {}
                    (a, b) => {
                        assert!(
                            !matches!(name, "bb" | "mckp"),
                            "trial {trial}: {name} feasibility flipped: {a:?} vs {b:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn exact_solvers_agree_through_the_engine() {
        // Tiny MACs keep the cap small enough for a unit DP grid, so the
        // DP is provably exact rather than accidentally lossless.
        let meta = crate::models::synthetic_meta(6, |i| 10 * (i as u64 + 1));
        let imp = IndicatorStore::init_uniform(&meta).importance(&meta);
        let e = PolicyEngine::new(meta, imp);
        let cap = uniform_bitops(&e.meta, 3, 3);
        let bb = SearchRequest::builder().bitops_cap(cap).solver_name("bb").build().unwrap();
        let dp = SearchRequest::builder()
            .bitops_cap(cap)
            .solver_name("mckp")
            .dp_grid(cap as usize + 1)
            .build()
            .unwrap();
        let a = e.solve_uncached(&bb).unwrap();
        let b = e.solve_uncached(&dp).unwrap();
        assert!(b.stats.proven_optimal, "unit-grid DP must be exact");
        assert!(
            (a.solution.cost - b.solution.cost).abs() < 1e-9,
            "bb {} vs dp {}",
            a.solution.cost,
            b.solution.cost
        );
    }

    #[test]
    fn stats_carry_bound_gap_and_effort() {
        let e = engine();
        let cap = uniform_bitops(&e.meta, 4, 4);
        let req = SearchRequest::builder().bitops_cap(cap).build().unwrap();
        let out = e.solve_uncached(&req).unwrap();
        assert_eq!(out.stats.solver, "bb");
        assert!(out.stats.nodes >= 1);
        assert!(out.stats.proven_optimal);
        let gap = out.stats.bound_gap.expect("bb certifies a root bound");
        assert!(gap >= -1e-9, "negative bound gap {gap}");
    }

    /// Counts invocations, sleeps long enough that a stampede of callers
    /// provably overlaps, then delegates to the real B&B solver.
    struct SlowSolver {
        calls: Arc<AtomicUsize>,
        delay: std::time::Duration,
    }

    impl Solver for SlowSolver {
        fn name(&self) -> &'static str {
            "slow"
        }
        fn supports(&self, _p: &crate::search::MpqProblem) -> bool {
            true
        }
        fn solve_full(
            &self,
            p: &crate::search::MpqProblem,
            budget: &SolveBudget,
        ) -> Result<SolveOutcome> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(self.delay);
            BranchAndBound.solve_full(p, budget)
        }
    }

    /// Counts invocations and always fails.
    struct FailSolver {
        calls: Arc<AtomicUsize>,
    }

    impl Solver for FailSolver {
        fn name(&self) -> &'static str {
            "fail"
        }
        fn supports(&self, _p: &crate::search::MpqProblem) -> bool {
            true
        }
        fn solve_full(
            &self,
            _p: &crate::search::MpqProblem,
            _budget: &SolveBudget,
        ) -> Result<SolveOutcome> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            anyhow::bail!("deliberately broken solver")
        }
    }

    fn engine_with(solver: Arc<dyn Solver>) -> PolicyEngine {
        let meta = meta6();
        let imp = IndicatorStore::init_uniform(&meta).importance(&meta);
        let registry: &'static SolverRegistry =
            Box::leak(Box::new(SolverRegistry::with_solvers(vec![solver])));
        PolicyEngine::with_registry(meta, imp, DEFAULT_CACHE_CAPACITY, registry)
    }

    #[test]
    fn concurrent_identical_cold_requests_single_flight_to_one_solve() {
        let calls = Arc::new(AtomicUsize::new(0));
        let e = engine_with(Arc::new(SlowSolver {
            calls: calls.clone(),
            delay: std::time::Duration::from_millis(150),
        }));
        let cap = uniform_bitops(&e.meta, 4, 4);
        let req = SearchRequest::builder()
            .alpha(2.0)
            .bitops_cap(cap)
            .solver_name("slow")
            .build()
            .unwrap();
        const N: usize = 8;
        let barrier = std::sync::Barrier::new(N);
        let outcomes: Vec<EngineResponse> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..N)
                .map(|_| {
                    s.spawn(|| {
                        barrier.wait();
                        e.solve(&req).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // One leader ran the solver; every follower shared its outcome.
        assert_eq!(calls.load(Ordering::SeqCst), 1, "stampede must cost one solve");
        let stats = e.cache_stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.hits, N - 1);
        // Every follower either waited in-flight or (if descheduled past
        // the leader's finish) hit the cache; with a 150 ms solve at
        // least one must have overlapped the leader.
        assert!(
            (1..=N - 1).contains(&stats.inflight_waits),
            "inflight_waits {} out of range",
            stats.inflight_waits
        );
        let leader_hits = outcomes.iter().filter(|o| !o.cache_hit).count();
        assert_eq!(leader_hits, 1);
        for o in &outcomes {
            assert_eq!(o.outcome.policy, outcomes[0].outcome.policy);
            assert!(Arc::ptr_eq(&o.outcome, &outcomes[0].outcome), "outcome must be shared");
        }
    }

    #[test]
    fn single_flight_propagates_errors_and_allows_retry() {
        let calls = Arc::new(AtomicUsize::new(0));
        let e = engine_with(Arc::new(FailSolver { calls: calls.clone() }));
        let cap = uniform_bitops(&e.meta, 4, 4);
        let req = SearchRequest::builder()
            .bitops_cap(cap)
            .solver_name("fail")
            .build()
            .unwrap();
        assert!(e.solve(&req).is_err());
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        // Failures are not cached and the in-flight slot is cleared:
        // a retry reaches the solver again instead of hanging or hitting
        // a poisoned entry.
        assert!(e.solve(&req).is_err());
        assert_eq!(calls.load(Ordering::SeqCst), 2);
        assert_eq!(e.cache_stats().misses, 0);
        assert_eq!(e.cache_stats().entries, 0);
    }

    /// Panics on every call — the fault the engine's firewall must absorb.
    struct PanicSolver;

    impl Solver for PanicSolver {
        fn name(&self) -> &'static str {
            "panic"
        }
        fn supports(&self, _p: &crate::search::MpqProblem) -> bool {
            true
        }
        fn solve_full(
            &self,
            _p: &crate::search::MpqProblem,
            _budget: &SolveBudget,
        ) -> Result<SolveOutcome> {
            panic!("boom")
        }
    }

    /// Succeeds once (delegating to B&B), then panics forever.
    struct FlakySolver {
        calls: Arc<AtomicUsize>,
    }

    impl Solver for FlakySolver {
        fn name(&self) -> &'static str {
            "flaky"
        }
        fn supports(&self, _p: &crate::search::MpqProblem) -> bool {
            true
        }
        fn solve_full(
            &self,
            p: &crate::search::MpqProblem,
            budget: &SolveBudget,
        ) -> Result<SolveOutcome> {
            if self.calls.fetch_add(1, Ordering::SeqCst) == 0 {
                BranchAndBound.solve_full(p, budget)
            } else {
                panic!("flaky fault")
            }
        }
    }

    #[test]
    fn solver_panic_degrades_to_greedy_and_is_never_cached() {
        let e = engine_with(Arc::new(PanicSolver));
        let cap = uniform_bitops(&e.meta, 4, 4);
        let req = SearchRequest::builder()
            .bitops_cap(cap)
            .solver_name("panic")
            .build()
            .unwrap();
        let resp = e.solve(&req).unwrap();
        let stats = &resp.outcome.stats;
        assert!(stats.degraded);
        assert_eq!(stats.solver, "greedy");
        let reason = stats.degraded_reason.as_deref().unwrap();
        assert!(reason.starts_with(PANIC_REASON), "{reason}");
        assert!(reason.contains("boom"), "{reason}");
        assert!(resp.outcome.solution.bitops <= cap, "degraded answer must stay feasible");
        // Never cached: the retry reaches the (still broken) solver again
        // instead of being pinned to a degraded answer forever.
        assert_eq!(e.cache_stats().entries, 0);
        let again = e.solve(&req).unwrap();
        assert!(!again.cache_hit);
        assert!(again.outcome.stats.degraded);
    }

    #[test]
    fn cancelled_leader_propagates_degraded_result_to_followers() {
        let calls = Arc::new(AtomicUsize::new(0));
        let e = engine_with(Arc::new(SlowSolver {
            calls: calls.clone(),
            delay: std::time::Duration::from_millis(200),
        }));
        let cap = uniform_bitops(&e.meta, 4, 4);
        // The leader carries the short deadline; followers are patient
        // (same canonical key — tokens never enter request identity), so
        // they wait the leader out and must share whatever it publishes.
        let leader_req = SearchRequest::builder()
            .bitops_cap(cap)
            .solver_name("slow")
            .cancel(CancelToken::after(std::time::Duration::from_millis(30)))
            .build()
            .unwrap();
        let follower_req =
            SearchRequest::builder().bitops_cap(cap).solver_name("slow").build().unwrap();
        const FOLLOWERS: usize = 3;
        let outcomes: Vec<EngineResponse> = std::thread::scope(|s| {
            let leader = s.spawn(|| e.solve(&leader_req).unwrap());
            // Join while the leader is still inside its 200 ms solve.
            std::thread::sleep(std::time::Duration::from_millis(50));
            let handles: Vec<_> =
                (0..FOLLOWERS).map(|_| s.spawn(|| e.solve(&follower_req).unwrap())).collect();
            let mut all = vec![leader.join().unwrap()];
            all.extend(handles.into_iter().map(|h| h.join().unwrap()));
            all
        });
        // The deadline fires while the leader sleeps inside the solver;
        // B&B then salvages its root incumbent.  Followers must share
        // that degraded outcome, not receive a leader-failed error.
        assert_eq!(calls.load(Ordering::SeqCst), 1, "single flight must hold under cancellation");
        for o in &outcomes {
            assert!(o.outcome.stats.degraded, "follower saw a non-degraded outcome");
            assert!(Arc::ptr_eq(&o.outcome, &outcomes[0].outcome), "outcome must be shared");
        }
        assert_eq!(e.cache_stats().entries, 0, "degraded outcomes must not enter the cache");
    }

    #[test]
    fn follower_deadline_fires_during_anothers_solve_and_degrades_on_time() {
        let calls = Arc::new(AtomicUsize::new(0));
        let e = engine_with(Arc::new(SlowSolver {
            calls: calls.clone(),
            delay: std::time::Duration::from_millis(500),
        }));
        let cap = uniform_bitops(&e.meta, 4, 4);
        // Patient leader, impatient follower: the follower's own 40 ms
        // deadline expires long before the leader's 500 ms solve returns,
        // so it must degrade under its own supervision instead of
        // inheriting the leader's (previously it blocked the full 500 ms).
        let leader_req =
            SearchRequest::builder().bitops_cap(cap).solver_name("slow").build().unwrap();
        let follower_req = SearchRequest::builder()
            .bitops_cap(cap)
            .solver_name("slow")
            .cancel(CancelToken::after(std::time::Duration::from_millis(40)))
            .build()
            .unwrap();
        std::thread::scope(|s| {
            let leader = s.spawn(|| e.solve(&leader_req).unwrap());
            std::thread::sleep(std::time::Duration::from_millis(60));
            let t = Instant::now();
            let resp = e.solve(&follower_req).unwrap();
            let waited = t.elapsed();
            assert!(
                waited < std::time::Duration::from_millis(300),
                "follower ignored its own deadline and waited {waited:?} on the leader"
            );
            assert!(!resp.cache_hit);
            let stats = &resp.outcome.stats;
            assert!(stats.degraded);
            assert_eq!(stats.solver, "greedy");
            assert!(
                stats.degraded_reason.as_deref().unwrap().contains("waiting"),
                "{:?}",
                stats.degraded_reason
            );
            assert!(resp.outcome.solution.bitops <= cap, "degraded answer must stay feasible");
            // The leader itself is untouched: clean solve, cached.
            let led = leader.join().unwrap();
            assert!(!led.outcome.stats.degraded);
        });
        assert_eq!(calls.load(Ordering::SeqCst), 1, "the follower must not have raced a solve");
    }

    #[test]
    fn solve_degraded_prefers_cache_then_greedy() {
        let e = engine();
        let cap = uniform_bitops(&e.meta, 4, 4);
        let req = SearchRequest::builder().bitops_cap(cap).build().unwrap();
        // Cold: the shed path answers via direct greedy, marked degraded.
        let shed = e.solve_degraded(&req, "breaker open").unwrap();
        assert!(shed.outcome.stats.degraded);
        assert_eq!(shed.outcome.stats.solver, "greedy");
        assert_eq!(shed.outcome.stats.degraded_reason.as_deref(), Some("breaker open"));
        assert!(shed.outcome.solution.bitops <= cap);
        // Warm: a real cached answer beats the fallback chain.
        let real = e.solve(&req).unwrap();
        assert!(!real.outcome.stats.degraded);
        let warm = e.solve_degraded(&req, "breaker open").unwrap();
        assert!(warm.cache_hit);
        assert!(!warm.outcome.stats.degraded);
    }

    #[test]
    fn last_good_fallback_honors_the_live_requests_caps() {
        let calls = Arc::new(AtomicUsize::new(0));
        let e = engine_with(Arc::new(FlakySolver { calls }));
        let cap = uniform_bitops(&e.meta, 4, 4);
        let good_req = SearchRequest::builder()
            .bitops_cap(cap)
            .solver_name("flaky")
            .build()
            .unwrap();
        let good = e.solve(&good_req).unwrap();
        assert!(!good.outcome.stats.degraded);
        // Second request: the solver panics AND greedy cannot repair the
        // hopeless 1-bitop cap.  The last clean policy exists but blows
        // this request's cap, so the chain must refuse — answering
        // `ok` with an over-cap policy would bust the client's stated
        // hardware budget — and the original panic surfaces as the error.
        let hopeless = SearchRequest::builder()
            .bitops_cap(1)
            .solver_name("flaky")
            .build()
            .unwrap();
        let err = e.solve(&hopeless).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.starts_with(PANIC_REASON), "{msg}");
        // A stale policy that *does* fit the live caps is still served.
        // Greedy only fails on these synthetic metas when the cap is
        // hopeless, so fabricate a fitting last_good to reach the branch.
        let mut doctored = (*good.outcome).clone();
        doctored.solution.bitops = 1;
        doctored.solution.size_bits = 0;
        *e.last_good.lock().unwrap() = Some(Arc::new(doctored));
        let served = e.fallback_outcome(&hopeless, "solver panicked: boom").unwrap();
        assert!(served.stats.degraded);
        let reason = served.stats.degraded_reason.as_deref().unwrap();
        assert!(reason.starts_with(PANIC_REASON), "{reason}");
        assert!(reason.contains("last good"), "{reason}");
        assert_eq!(served.policy, good.outcome.policy);
    }

    #[test]
    fn lru_evicts_under_many_distinct_requests() {
        let meta = meta6();
        let imp = IndicatorStore::init_uniform(&meta).importance(&meta);
        let e = PolicyEngine::with_cache_capacity(meta, imp, 4);
        let base = uniform_bitops(&e.meta, 4, 4);
        for i in 0..8u64 {
            let req = SearchRequest::builder().bitops_cap(base + i).build().unwrap();
            e.solve(&req).unwrap();
        }
        let stats = e.cache_stats();
        assert_eq!(stats.entries, 4);
        assert_eq!(stats.misses, 8);
        // oldest request was evicted -> re-solving it is a miss
        let req = SearchRequest::builder().bitops_cap(base).build().unwrap();
        assert!(!e.solve(&req).unwrap().cache_hit);
    }
}
