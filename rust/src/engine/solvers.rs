//! The [`Solver`] trait and its implementations — one per solver family
//! in the from-scratch ILP stack:
//!
//! * [`BranchAndBound`] (`bb`) — exact Lagrangian B&B, any constraint set
//! * [`MckpDp`] (`mckp`) — dynamic program, exactly one constraint
//! * [`SimplexRelax`] (`lp-round`) — LP relaxation + guided rounding,
//!   reports the relaxation value as a certified lower bound; above
//!   [`FINE_GRAIN_VARS`] variables it swaps the dense simplex for the
//!   parallel Lagrangian decomposition in `search::lagrange`
//!
//! [`FINE_GRAIN_VARS`]: crate::search::FINE_GRAIN_VARS
//! * [`ParetoFrontier`] (`pareto`) — HAWQ-v2-style frontier sweep
//! * [`GreedyRepair`] (`greedy`) — constructive argmin + ratio repair
//!
//! All are stateless and `Send + Sync`, so one registry instance serves
//! every fleet thread.  Cross-validated against `brute_force` through
//! trait objects in the tests below.

use anyhow::{bail, Result};

use super::request::SolveBudget;
use crate::search::lp::{Lp, LpOutcome};
use crate::search::mckp::{solve_dp_stats, Resource};
use crate::search::pareto::solve_pareto;
use crate::search::{bb::solve_bb_stats, repair_to_feasible, MpqProblem, Solution};

/// What a solver hands back besides the solution itself.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    pub solution: Solution,
    /// Search effort in solver-native units (B&B nodes, DP cell
    /// relaxations; 0 where the notion does not apply).
    pub nodes: u64,
    /// Certified lower bound on the optimal cost, when the solver proves
    /// one (B&B root bound, LP relaxation value).
    pub lower_bound: Option<f64>,
    /// True when the returned solution is provably optimal.
    pub proven_optimal: bool,
    /// Set when the budget's [`CancelToken`] fired mid-solve and the
    /// solver salvaged an incumbent anyway (B&B).  The engine treats such
    /// outcomes as degraded: returned to the caller, never cached.
    ///
    /// [`CancelToken`]: super::request::CancelToken
    pub cancelled: bool,
    /// Options removed by MCKP dominance preprocessing before the solve.
    /// Solvers themselves report 0; the registry's pruning hook fills it
    /// in when it solves the reduced problem.
    pub pruned: usize,
}

/// A pluggable MPQ policy solver.
pub trait Solver: Send + Sync {
    /// Registry name (also the CLI `--solver` / fleet `"solver"` value).
    fn name(&self) -> &'static str;

    /// Whether this solver can handle the problem's constraint shape.
    fn supports(&self, p: &MpqProblem) -> bool;

    /// Solve within the budget (the narrow, issue-facing entry point).
    fn solve(&self, p: &MpqProblem, budget: &SolveBudget) -> Result<Solution> {
        self.solve_full(p, budget).map(|o| o.solution)
    }

    /// Solve and report effort/bound telemetry.
    fn solve_full(&self, p: &MpqProblem, budget: &SolveBudget) -> Result<SolveOutcome>;
}

// ---------------------------------------------------------------------------
// bb
// ---------------------------------------------------------------------------

/// Exact branch-and-bound (`search::bb`) behind the trait.
pub struct BranchAndBound;

impl Solver for BranchAndBound {
    fn name(&self) -> &'static str {
        "bb"
    }

    fn supports(&self, _p: &MpqProblem) -> bool {
        true
    }

    fn solve_full(&self, p: &MpqProblem, budget: &SolveBudget) -> Result<SolveOutcome> {
        let (solution, stats) =
            solve_bb_stats(p, budget.node_limit, budget.deadline(), &budget.cancel)?;
        Ok(SolveOutcome {
            solution,
            nodes: stats.nodes,
            lower_bound: Some(stats.root_bound),
            proven_optimal: stats.proven_optimal,
            cancelled: stats.cancelled,
            pruned: 0,
        })
    }
}

// ---------------------------------------------------------------------------
// mckp
// ---------------------------------------------------------------------------

/// MCKP dynamic program (`search::mckp`); single-constraint problems only.
pub struct MckpDp;

impl Solver for MckpDp {
    fn name(&self) -> &'static str {
        "mckp"
    }

    fn supports(&self, p: &MpqProblem) -> bool {
        p.bitops_cap.is_some() != p.size_cap_bits.is_some()
    }

    fn solve_full(&self, p: &MpqProblem, budget: &SolveBudget) -> Result<SolveOutcome> {
        let resource = match (p.bitops_cap, p.size_cap_bits) {
            (Some(_), None) => Resource::BitOps,
            (None, Some(_)) => Resource::SizeBits,
            _ => bail!("mckp DP needs exactly one constraint"),
        };
        let (solution, dp) = solve_dp_stats(p, resource, budget.dp_grid, &budget.cancel)?;
        Ok(SolveOutcome {
            solution,
            nodes: dp.cells as u64 * p.n_vars() as u64,
            lower_bound: None,
            // Exact whenever the cap fits the grid without rounding.
            proven_optimal: dp.unit == 1,
            cancelled: false,
            pruned: 0,
        })
    }
}

// ---------------------------------------------------------------------------
// lp-round
// ---------------------------------------------------------------------------

/// LP relaxation (two-phase simplex) + guided rounding.  The relaxation
/// value is a certified lower bound; the rounded policy is repaired to
/// feasibility with the same ratio-greedy move the B&B incumbent uses.
///
/// The dense simplex tableau is O(n²) in the variable count, so above
/// [`crate::search::FINE_GRAIN_VARS`] variables (channel-group / kernel
/// granularity) the solve routes to the Lagrangian decomposition instead:
/// same certified-lower-bound contract, per-group argmins parallelized
/// over the worker pool, bit-identical at any thread count.
pub struct SimplexRelax;

impl SimplexRelax {
    /// Build the MCKP LP relaxation: one column per option, choose-one
    /// equality row per group, one ≤ row per active cap (normalized to
    /// rhs 1 for conditioning).
    fn relaxation(p: &MpqProblem) -> Lp {
        let n: usize = p.n_vars();
        let mut c = Vec::with_capacity(n);
        let mut a_eq = Vec::with_capacity(p.groups.len());
        let mut col = 0usize;
        for opts in &p.groups {
            let mut row = vec![0.0; n];
            for o in opts {
                c.push(o.cost);
                row[col] = 1.0;
                col += 1;
            }
            a_eq.push(row);
        }
        let mut a_ub = Vec::new();
        let mut b_ub = Vec::new();
        if let Some(cap) = p.bitops_cap {
            let cap = cap.max(1) as f64;
            let mut row = Vec::with_capacity(n);
            for opts in &p.groups {
                for o in opts {
                    row.push(o.bitops as f64 / cap);
                }
            }
            a_ub.push(row);
            b_ub.push(1.0);
        }
        if let Some(cap) = p.size_cap_bits {
            let cap = cap.max(1) as f64;
            let mut row = Vec::with_capacity(n);
            for opts in &p.groups {
                for o in opts {
                    row.push(o.size_bits as f64 / cap);
                }
            }
            a_ub.push(row);
            b_ub.push(1.0);
        }
        let b_eq = vec![1.0; p.groups.len()];
        Lp { c, a_ub, b_ub, a_eq, b_eq }
    }
}

impl Solver for SimplexRelax {
    fn name(&self) -> &'static str {
        "lp-round"
    }

    fn supports(&self, p: &MpqProblem) -> bool {
        !p.groups.is_empty()
    }

    fn solve_full(&self, p: &MpqProblem, budget: &SolveBudget) -> Result<SolveOutcome> {
        if p.groups.iter().any(|o| o.is_empty()) {
            bail!("a group has no options");
        }
        // Fine-grained route: the dense tableau would be quadratic in
        // 10k+ variables; the decomposed dual solve is linear per
        // evaluation and parallel, with the same bound contract.
        if p.n_vars() > crate::search::FINE_GRAIN_VARS {
            let pool = crate::kernels::pool::WorkerPool::global();
            let (solution, stats) =
                crate::search::lagrange::solve_lagrange(p, &pool, budget.deadline(), &budget.cancel)?;
            return Ok(SolveOutcome {
                solution,
                nodes: stats.evals,
                lower_bound: Some(stats.bound),
                proven_optimal: stats.proven_optimal,
                cancelled: stats.cancelled,
                pruned: 0,
            });
        }
        let (x, lp_obj) = match Self::relaxation(p).solve_supervised(&budget.cancel)? {
            LpOutcome::Optimal { x, obj } => (x, obj),
            LpOutcome::Infeasible => bail!("LP relaxation infeasible"),
            LpOutcome::Unbounded => bail!("LP relaxation unbounded (malformed problem)"),
        };
        // Round: per group take the option with the largest fractional
        // mass (ties to the lighter option so rounding leans feasible).
        let mut choice = Vec::with_capacity(p.groups.len());
        let mut col = 0usize;
        for opts in &p.groups {
            let mut best = 0usize;
            let mut best_mass = f64::MIN;
            for (i, o) in opts.iter().enumerate() {
                let mass = x[col + i];
                let better = mass > best_mass + 1e-12
                    || ((mass - best_mass).abs() <= 1e-12 && o.bitops < opts[best].bitops);
                if better {
                    best = i;
                    best_mass = mass;
                }
            }
            choice.push(best);
            col += opts.len();
        }
        let solution = repair_to_feasible(p, &choice)
            .ok_or_else(|| anyhow::anyhow!("could not repair LP rounding to feasibility"))?;
        let proven = p.feasible(&solution) && (solution.cost - lp_obj).abs() <= 1e-9;
        Ok(SolveOutcome {
            solution,
            nodes: 0,
            lower_bound: Some(lp_obj),
            proven_optimal: proven,
            cancelled: false,
            pruned: 0,
        })
    }
}

// ---------------------------------------------------------------------------
// pareto
// ---------------------------------------------------------------------------

/// HAWQ-v2-style Lagrangian frontier sweep (`search::pareto`).  Reaches
/// convex-hull points only, so it can miss interior optima — in the
/// fallback chain it sits after the exact solvers.
pub struct ParetoFrontier;

impl Solver for ParetoFrontier {
    fn name(&self) -> &'static str {
        "pareto"
    }

    fn supports(&self, p: &MpqProblem) -> bool {
        !p.groups.is_empty()
    }

    fn solve_full(&self, p: &MpqProblem, budget: &SolveBudget) -> Result<SolveOutcome> {
        let solution = solve_pareto(p, budget.pareto_steps)?;
        Ok(SolveOutcome {
            solution,
            nodes: budget.pareto_steps as u64,
            lower_bound: None,
            proven_optimal: false,
            cancelled: false,
            pruned: 0,
        })
    }
}

// ---------------------------------------------------------------------------
// greedy
// ---------------------------------------------------------------------------

/// Constructive heuristic: per-group cost argmin, then ratio-greedy
/// repair toward the caps.  Never optimal by proof, but always fast and
/// supports every constraint shape — the registry's last resort.
pub struct GreedyRepair;

impl Solver for GreedyRepair {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn supports(&self, p: &MpqProblem) -> bool {
        !p.groups.is_empty()
    }

    fn solve_full(&self, p: &MpqProblem, _budget: &SolveBudget) -> Result<SolveOutcome> {
        if p.groups.iter().any(|o| o.is_empty()) {
            bail!("a group has no options");
        }
        let choice: Vec<usize> = p
            .groups
            .iter()
            .map(|opts| {
                opts.iter()
                    .enumerate()
                    .min_by(|(_, a), (_, b)| a.cost.partial_cmp(&b.cost).unwrap())
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect();
        let solution = repair_to_feasible(p, &choice)
            .ok_or_else(|| anyhow::anyhow!("greedy repair could not reach feasibility"))?;
        Ok(SolveOutcome {
            solution,
            nodes: 0,
            lower_bound: None,
            proven_optimal: false,
            cancelled: false,
            pruned: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::testutil::random_problem;
    use crate::util::rng::Rng;

    fn all_solvers() -> Vec<Box<dyn Solver>> {
        vec![
            Box::new(BranchAndBound),
            Box::new(MckpDp),
            Box::new(SimplexRelax),
            Box::new(ParetoFrontier),
            Box::new(GreedyRepair),
        ]
    }

    /// Every solver, through the trait object, against brute force: exact
    /// solvers must match the optimum; heuristics must stay feasible and
    /// never beat it.
    #[test]
    fn all_impls_cross_validate_against_brute_force() {
        let mut rng = Rng::new(2024);
        let solvers = all_solvers();
        let mut solved = vec![0usize; solvers.len()];
        for trial in 0..40 {
            let layers = 2 + rng.below(4);
            let opts = 2 + rng.below(3);
            let tight = rng.uniform(0.1, 0.95);
            let p = random_problem(&mut rng, layers, opts, tight);
            let Some(bf) = p.brute_force() else { continue };
            // unit-grid DP stays exact on these small caps
            let budget = SolveBudget {
                dp_grid: p.bitops_cap.unwrap() as usize + 1,
                ..SolveBudget::default()
            };
            for (si, s) in solvers.iter().enumerate() {
                if !s.supports(&p) {
                    continue;
                }
                let out = match s.solve_full(&p, &budget) {
                    Ok(o) => o,
                    // heuristics may legitimately miss a feasible point
                    Err(_) if matches!(s.name(), "pareto" | "greedy" | "lp-round") => continue,
                    Err(e) => panic!("trial {trial}: {} failed: {e:#}", s.name()),
                };
                solved[si] += 1;
                assert!(p.feasible(&out.solution), "trial {trial}: {} infeasible", s.name());
                assert!(
                    out.solution.cost >= bf.cost - 1e-9,
                    "trial {trial}: {} beat brute force ({} < {})",
                    s.name(),
                    out.solution.cost,
                    bf.cost
                );
                if let Some(lb) = out.lower_bound {
                    assert!(
                        lb <= bf.cost + 1e-6,
                        "trial {trial}: {} lower bound {lb} above optimum {}",
                        s.name(),
                        bf.cost
                    );
                }
                if out.proven_optimal || matches!(s.name(), "bb" | "mckp") {
                    assert!(
                        (out.solution.cost - bf.cost).abs() < 1e-9,
                        "trial {trial}: {} cost {} vs optimum {}",
                        s.name(),
                        out.solution.cost,
                        bf.cost
                    );
                }
            }
        }
        // every solver must have actually exercised its solve path
        for (si, s) in solvers.iter().enumerate() {
            assert!(solved[si] > 0, "{} never solved an instance", s.name());
        }
    }

    #[test]
    fn narrow_solve_entry_matches_full() {
        let mut rng = Rng::new(7);
        let p = random_problem(&mut rng, 4, 4, 0.6);
        let b = SolveBudget::default();
        let full = BranchAndBound.solve_full(&p, &b).unwrap();
        let narrow = BranchAndBound.solve(&p, &b).unwrap();
        assert_eq!(narrow, full.solution);
    }

    #[test]
    fn mckp_declines_two_constraint_problems() {
        let mut rng = Rng::new(8);
        let mut p = random_problem(&mut rng, 3, 3, 0.5);
        p.size_cap_bits = Some(1 << 40);
        assert!(!MckpDp.supports(&p));
        assert!(BranchAndBound.supports(&p));
        assert!(SimplexRelax.supports(&p));
    }

    #[test]
    fn lp_round_bound_gap_is_nonnegative() {
        let mut rng = Rng::new(9);
        for _ in 0..10 {
            let p = random_problem(&mut rng, 5, 4, 0.5);
            if let Ok(out) = SimplexRelax.solve_full(&p, &SolveBudget::default()) {
                let lb = out.lower_bound.unwrap();
                assert!(out.solution.cost >= lb - 1e-9);
            }
        }
    }

    #[test]
    fn greedy_unconstrained_is_min_cost() {
        let mut rng = Rng::new(10);
        let mut p = random_problem(&mut rng, 5, 4, 1.0);
        p.bitops_cap = None;
        let out = GreedyRepair.solve_full(&p, &SolveBudget::default()).unwrap();
        let want: f64 =
            p.groups.iter().map(|o| o.iter().map(|x| x.cost).fold(f64::MAX, f64::min)).sum();
        assert!((out.solution.cost - want).abs() < 1e-9);
    }

    /// The fine-grained `lp-round` route (Lagrangian decomposition) obeys
    /// the same contract as the dense simplex: feasible solution, cost
    /// never below the certified lower bound.
    #[test]
    fn lp_round_fine_route_keeps_bound_contract() {
        let mut rng = Rng::new(0xF17E);
        // 600 groups × 4 options = 2400 vars > FINE_GRAIN_VARS (2000).
        let p = random_problem(&mut rng, 600, 4, 0.5);
        assert!(p.n_vars() > crate::search::FINE_GRAIN_VARS);
        let out = SimplexRelax.solve_full(&p, &SolveBudget::default()).unwrap();
        assert!(p.feasible(&out.solution));
        let lb = out.lower_bound.expect("fine route must certify a bound");
        assert!(out.solution.cost >= lb - 1e-9, "cost {} below bound {lb}", out.solution.cost);
        assert!(out.nodes > 0, "dual evaluations must be reported as effort");
    }
}
