//! Certified Pareto-frontier surfaces — precomputed multi-constraint
//! serving (the fleet's O(1) hot path, by construction).
//!
//! The paper's efficiency pitch is that once layer-wise importances are
//! learned, re-search per deployment target is nearly free.  At fleet
//! scale most device queries are just points on one trade-off surface,
//! so instead of a fresh MCKP solve per (bitops, size) cap pair we sweep
//! the two-dimensional Lagrangian space **once** per model and serve
//! every later query from the resulting surface:
//!
//! * [`FrontierBuilder`] generalizes the 1-D λ sweep of
//!   [`crate::search::pareto`] to two multipliers (λ_bitops, λ_size).
//!   Every swept dual point yields (a) a primal policy — the per-layer
//!   penalized argmin — and (b) a dual value `g(λ)` that certifies a
//!   lower bound for *any* cap pair: `LB(B,S) = g(λ) − λ_b·B − λ_s·S`.
//!   The deduplicated, non-dominated policies become
//!   [`FrontierVertex`]s; the dual values are kept as certificates.
//! * [`FrontierIndex`] answers a constraint query by picking the
//!   cheapest vertex fitting both caps and comparing its cost against
//!   the best certificate: the answer is a **hit** only when the gap is
//!   within a configurable relative tolerance, so a frontier answer is
//!   never silently worse than `tolerance` × its own cost.  Anything
//!   else is a miss — the caller runs an exact engine solve and feeds
//!   the result back via [`FrontierIndex::refine`], which inserts the
//!   policy as a refining vertex and (for proven-optimal solves) the
//!   achieved cost as an exact bound point.  Repeats of a refined cap
//!   pair therefore hit with gap 0.
//! * [`FrontierSet`] holds one lazily-built, single-flighted index per
//!   (α, weight_only) surface — the same publish/wait discipline as
//!   registry model loads — and lives on
//!   [`crate::registry::ModelEntry`], so surfaces are byte-accounted
//!   toward `--mem-budget-mb` and evicted with their model.
//!
//! The fleet dispatcher ([`crate::fleet::dispatch`]) consults the
//! frontier *before* the per-model policy cache; see the fleet module
//! docs for the full lookup order and the `{"cmd":"frontier"}` admin
//! command.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use anyhow::{anyhow, bail, Result};

use crate::quant::BitConfig;
use crate::search::MpqProblem;

/// One non-dominated point on a model's trade-off surface.
#[derive(Debug, Clone)]
pub struct FrontierVertex {
    pub policy: BitConfig,
    pub cost: f64,
    pub bitops: u64,
    pub size_bits: u64,
    /// True when this vertex came from an exact engine solve fed back
    /// through [`FrontierIndex::refine`] rather than the dual sweep.
    pub refined: bool,
}

impl FrontierVertex {
    /// `self` makes `other` redundant (no worse on every axis).
    fn dominates_or_ties(&self, other: &FrontierVertex) -> bool {
        self.cost <= other.cost
            && self.bitops <= other.bitops
            && self.size_bits <= other.size_bits
    }
}

/// A swept dual point: `g` is the Lagrangian value
/// Σ_l min_o (cost + λ_b·bitops + λ_s·size_bits), which lower-bounds the
/// optimum of any cap pair via `g − λ_b·B − λ_s·S` (an axis with no cap
/// only admits duals with λ = 0 on that axis).
#[derive(Debug, Clone, Copy)]
struct DualPoint {
    lambda_b: f64,
    lambda_s: f64,
    g: f64,
}

/// An exact optimum recorded at specific caps: any query whose caps are
/// componentwise at most these (missing cap = ∞) cannot do better.
#[derive(Debug, Clone, Copy)]
struct BoundPoint {
    bitops_cap: Option<u64>,
    size_cap_bits: Option<u64>,
    cost: f64,
}

/// `query ≤ bound` on one cap axis, treating `None` as ∞.
fn cap_le(query: Option<u64>, bound: Option<u64>) -> bool {
    match (query, bound) {
        (_, None) => true,
        (None, Some(_)) => false,
        (Some(q), Some(b)) => q <= b,
    }
}

/// The certified surface for one (α, weight_only) problem family.
#[derive(Debug, Clone)]
pub struct FrontierSurface {
    vertices: Vec<FrontierVertex>,
    duals: Vec<DualPoint>,
    bounds: Vec<BoundPoint>,
    /// Σ per-layer max |cost| — the natural cost magnitude of the
    /// problem, used only to absorb float noise in gap comparisons.
    cost_scale: f64,
}

impl FrontierSurface {
    pub fn n_vertices(&self) -> usize {
        self.vertices.len()
    }

    pub fn n_refined(&self) -> usize {
        self.vertices.iter().filter(|v| v.refined).count()
    }

    pub fn n_duals(&self) -> usize {
        self.duals.len()
    }

    pub fn n_bounds(&self) -> usize {
        self.bounds.len()
    }

    pub fn vertices(&self) -> &[FrontierVertex] {
        &self.vertices
    }

    /// Best certified lower bound on the optimum under the given caps
    /// (`NEG_INFINITY` when no certificate applies).
    pub fn lower_bound(&self, bitops_cap: Option<u64>, size_cap_bits: Option<u64>) -> f64 {
        let mut lb = f64::NEG_INFINITY;
        for d in &self.duals {
            if (bitops_cap.is_none() && d.lambda_b > 0.0)
                || (size_cap_bits.is_none() && d.lambda_s > 0.0)
            {
                continue;
            }
            let mut v = d.g;
            if let Some(cap) = bitops_cap {
                v -= d.lambda_b * cap as f64;
            }
            if let Some(cap) = size_cap_bits {
                v -= d.lambda_s * cap as f64;
            }
            lb = lb.max(v);
        }
        for b in &self.bounds {
            if cap_le(bitops_cap, b.bitops_cap) && cap_le(size_cap_bits, b.size_cap_bits) {
                lb = lb.max(b.cost);
            }
        }
        lb
    }

    /// Cheapest vertex feasible under both caps, if any.  Ties prefer
    /// refined (exact-solve) vertices, then tighter resource use, so a
    /// refined cap pair replays the exact policy byte-for-byte.
    pub fn best_vertex(
        &self,
        bitops_cap: Option<u64>,
        size_cap_bits: Option<u64>,
    ) -> Option<&FrontierVertex> {
        self.vertices
            .iter()
            .filter(|v| {
                bitops_cap.map_or(true, |c| v.bitops <= c)
                    && size_cap_bits.map_or(true, |c| v.size_bits <= c)
            })
            .min_by(|x, y| {
                x.cost
                    .partial_cmp(&y.cost)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then_with(|| x.refined.cmp(&y.refined).reverse())
                    .then_with(|| x.bitops.cmp(&y.bitops))
                    .then_with(|| x.size_bits.cmp(&y.size_bits))
            })
    }

    /// Insert an exact-solve result as a refining vertex (dropped if an
    /// existing vertex already dominates it) and, when the solve proved
    /// optimality, an exact bound point at the query caps.  Returns an
    /// estimate of the bytes added.
    fn insert_refined(
        &mut self,
        vertex: FrontierVertex,
        bitops_cap: Option<u64>,
        size_cap_bits: Option<u64>,
        exact: bool,
    ) -> usize {
        let mut added = 0usize;
        // A swept vertex may tie the exact optimum on cost with a
        // *different* policy; insert the refined vertex anyway (the
        // query tie-break prefers refined) so a refined cap pair replays
        // the exact solve's policy verbatim.  Only an existing refined
        // vertex that is no worse everywhere makes this one redundant.
        if !self.vertices.iter().any(|u| u.refined && u.dominates_or_ties(&vertex)) {
            self.vertices.retain(|u| {
                !(vertex.dominates_or_ties(u)
                    && (vertex.cost < u.cost
                        || vertex.bitops < u.bitops
                        || vertex.size_bits < u.size_bits))
            });
            added += vertex_bytes(&vertex);
            self.vertices.push(vertex.clone());
        }
        if exact {
            let dup = self
                .bounds
                .iter_mut()
                .find(|b| b.bitops_cap == bitops_cap && b.size_cap_bits == size_cap_bits);
            match dup {
                // Two exact optima at the same caps must agree; keep the
                // tighter (larger) bound to shrug off float noise.
                Some(b) => b.cost = b.cost.max(vertex.cost),
                None => {
                    self.bounds.push(BoundPoint { bitops_cap, size_cap_bits, cost: vertex.cost });
                    added += std::mem::size_of::<BoundPoint>();
                }
            }
        }
        added
    }
}

fn vertex_bytes(v: &FrontierVertex) -> usize {
    96 + 2 * v.policy.w_bits.len()
}

fn surface_bytes(s: &FrontierSurface) -> usize {
    256 + s.vertices.iter().map(vertex_bytes).sum::<usize>()
        + s.duals.len() * std::mem::size_of::<DualPoint>()
        + s.bounds.len() * std::mem::size_of::<BoundPoint>()
}

/// Sweeps the 2-D Lagrangian space of an [`MpqProblem`] into a
/// [`FrontierSurface`] — the λ-grid generalization of
/// [`crate::search::pareto::frontier`].
#[derive(Debug, Clone, Copy)]
pub struct FrontierBuilder {
    /// Log-spaced multiplier points per axis (plus the λ = 0 line, which
    /// certifies queries that leave that axis uncapped).
    pub steps: usize,
}

impl FrontierBuilder {
    pub fn new(steps: usize) -> FrontierBuilder {
        FrontierBuilder { steps }
    }

    /// Build the certified surface.  The problem's own caps are ignored
    /// — the surface covers every cap pair at once.
    pub fn build(&self, p: &MpqProblem) -> Result<FrontierSurface> {
        if self.steps < 2 {
            bail!("frontier sweep needs at least 2 steps per axis");
        }
        if p.groups.is_empty() || p.groups.iter().any(|l| l.is_empty()) {
            bail!("frontier sweep needs a non-empty problem");
        }
        let cost_scale: f64 = p
            .groups
            .iter()
            .map(|l| l.iter().map(|o| o.cost.abs()).fold(0.0, f64::max))
            .sum::<f64>()
            .max(1e-9);
        let bitops_scale: f64 = p
            .groups
            .iter()
            .map(|l| l.iter().map(|o| o.bitops).max().unwrap_or(0) as f64)
            .sum::<f64>()
            .max(1.0);
        let size_scale: f64 = p
            .groups
            .iter()
            .map(|l| l.iter().map(|o| o.size_bits).max().unwrap_or(0) as f64)
            .sum::<f64>()
            .max(1.0);
        let axis_b = lambda_axis(cost_scale / bitops_scale, self.steps);
        let axis_s = lambda_axis(cost_scale / size_scale, self.steps);

        let n = p.n_groups();
        let mut duals = Vec::with_capacity(axis_b.len() * axis_s.len());
        let mut seen: HashSet<Vec<usize>> = HashSet::new();
        let mut candidates: Vec<FrontierVertex> = Vec::new();
        for &lb in &axis_b {
            for &ls in &axis_s {
                let mut choice = vec![0usize; n];
                let mut g = 0.0;
                for (l, opts) in p.groups.iter().enumerate() {
                    let mut best = 0usize;
                    let mut best_v = f64::INFINITY;
                    for (c, o) in opts.iter().enumerate() {
                        let v = o.cost + lb * o.bitops as f64 + ls * o.size_bits as f64;
                        if v < best_v {
                            best_v = v;
                            best = c;
                        }
                    }
                    choice[l] = best;
                    g += best_v;
                }
                duals.push(DualPoint { lambda_b: lb, lambda_s: ls, g });
                if seen.insert(choice.clone()) {
                    let sol = p.evaluate(&choice)?;
                    candidates.push(FrontierVertex {
                        policy: p.to_bit_config(&sol),
                        cost: sol.cost,
                        bitops: sol.bitops,
                        size_bits: sol.size_bits,
                        refined: false,
                    });
                }
            }
        }

        // Drop dominated candidates (keep the first of exact ties).
        let mut vertices: Vec<FrontierVertex> = Vec::new();
        for v in candidates {
            if vertices.iter().any(|u| u.dominates_or_ties(&v)) {
                continue;
            }
            vertices.retain(|u| !v.dominates_or_ties(u));
            vertices.push(v);
        }
        Ok(FrontierSurface { vertices, duals, bounds: Vec::new(), cost_scale })
    }
}

/// `[0] ++ steps` log-spaced multipliers spanning 1e-4·unit ..= 1e4·unit
/// (the same span [`crate::search::pareto`] sweeps in 1-D).
fn lambda_axis(unit: f64, steps: usize) -> Vec<f64> {
    let lo = 1e-4 * unit;
    let hi = 1e4 * unit;
    let mut axis = Vec::with_capacity(steps + 1);
    axis.push(0.0);
    for i in 0..steps {
        let t = i as f64 / (steps - 1).max(1) as f64;
        axis.push(lo * (hi / lo).powf(t));
    }
    axis
}

/// What a frontier answer carries back to the dispatcher.
#[derive(Debug, Clone)]
pub struct FrontierHit {
    pub policy: BitConfig,
    pub cost: f64,
    pub bitops: u64,
    pub size_bits: u64,
    /// Certified `cost − lower_bound` for this query.
    pub gap: f64,
}

/// Counter snapshot for `{"cmd":"frontier"}` / `{"cmd":"stats"}`.
#[derive(Debug, Clone, Copy)]
pub struct FrontierStats {
    pub vertices: usize,
    pub refined: usize,
    pub duals: usize,
    pub bounds: usize,
    pub hits: usize,
    pub misses: usize,
    pub refines: usize,
    pub bytes: usize,
}

/// A queryable surface with hit/miss/refine accounting.
#[derive(Debug)]
pub struct FrontierIndex {
    surface: RwLock<FrontierSurface>,
    /// Relative certificate tolerance: a vertex is served only when
    /// `cost − LB ≤ tolerance·|cost|` (plus float noise).  0 demands an
    /// exact certificate.
    tolerance: f64,
    hits: AtomicUsize,
    misses: AtomicUsize,
    refines: AtomicUsize,
    bytes: AtomicUsize,
}

impl FrontierIndex {
    pub fn new(surface: FrontierSurface, tolerance: f64) -> FrontierIndex {
        let bytes = surface_bytes(&surface);
        FrontierIndex {
            surface: RwLock::new(surface),
            tolerance,
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            refines: AtomicUsize::new(0),
            bytes: AtomicUsize::new(bytes),
        }
    }

    pub fn tolerance(&self) -> f64 {
        self.tolerance
    }

    /// Answer a cap query from the surface, or record a miss (no vertex
    /// fits, or the certificate gap exceeds the tolerance) so the caller
    /// falls back to an exact solve.
    pub fn query(&self, bitops_cap: Option<u64>, size_cap_bits: Option<u64>) -> Option<FrontierHit> {
        let hit = {
            let surf = self.surface.read().unwrap();
            surf.best_vertex(bitops_cap, size_cap_bits).and_then(|v| {
                let lb = surf.lower_bound(bitops_cap, size_cap_bits);
                let gap = if lb.is_finite() { (v.cost - lb).max(0.0) } else { f64::INFINITY };
                let allowed = self.tolerance * v.cost.abs() + 1e-12 * surf.cost_scale;
                (gap <= allowed).then(|| FrontierHit {
                    policy: v.policy.clone(),
                    cost: v.cost,
                    bitops: v.bitops,
                    size_bits: v.size_bits,
                    gap,
                })
            })
        };
        match hit {
            Some(h) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(h)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Feed an exact engine solve back into the surface.  `exact` marks
    /// a proven-optimal solve, which additionally certifies a bound
    /// point at the query caps (a heuristic incumbent only contributes
    /// its vertex — its cost is an upper bound, never a certificate).
    pub fn refine(
        &self,
        bitops_cap: Option<u64>,
        size_cap_bits: Option<u64>,
        policy: BitConfig,
        cost: f64,
        bitops: u64,
        size_bits: u64,
        exact: bool,
    ) {
        let vertex = FrontierVertex { policy, cost, bitops, size_bits, refined: true };
        let added = {
            let mut surf = self.surface.write().unwrap();
            surf.insert_refined(vertex, bitops_cap, size_cap_bits, exact)
        };
        self.refines.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(added, Ordering::Relaxed);
    }

    /// Approximate resident bytes (build estimate + refinements).
    pub fn bytes(&self) -> usize {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> FrontierStats {
        let surf = self.surface.read().unwrap();
        FrontierStats {
            vertices: surf.n_vertices(),
            refined: surf.n_refined(),
            duals: surf.n_duals(),
            bounds: surf.n_bounds(),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            refines: self.refines.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
        }
    }
}

/// Identifies one surface of a model: the problem family is fixed by
/// (α, weight_only, granularity) — caps vary per query and live *on*
/// the surface.  Granularity is part of the key because a channel-group
/// surface's policies have a different variable space than the
/// layer-wise surface of the same α.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SurfaceKey {
    alpha_bits: u64,
    weight_only: bool,
    granularity: crate::search::Granularity,
}

impl SurfaceKey {
    pub fn new(
        alpha: f64,
        weight_only: bool,
        granularity: crate::search::Granularity,
    ) -> SurfaceKey {
        // Collapse -0.0 onto 0.0 so the two hash identically.
        let alpha = if alpha == 0.0 { 0.0 } else { alpha };
        SurfaceKey { alpha_bits: alpha.to_bits(), weight_only, granularity }
    }

    pub fn alpha(&self) -> f64 {
        f64::from_bits(self.alpha_bits)
    }

    pub fn weight_only(&self) -> bool {
        self.weight_only
    }

    pub fn granularity(&self) -> crate::search::Granularity {
        self.granularity
    }
}

enum SlotState {
    Building,
    Ready(Arc<FrontierIndex>),
}

/// Per-model collection of lazily-built surfaces, single-flighted the
/// same way the registry single-flights model loads: the first caller
/// builds (lock released during the sweep), concurrent callers for the
/// same key wait on the condvar and share the published index.  A
/// failed or panicked build clears the slot so the next caller retries.
#[derive(Default)]
pub struct FrontierSet {
    slots: Mutex<HashMap<SurfaceKey, SlotState>>,
    ready: Condvar,
}

impl std::fmt::Debug for FrontierSet {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FrontierSet").finish_non_exhaustive()
    }
}

impl FrontierSet {
    pub fn new() -> FrontierSet {
        FrontierSet::default()
    }

    /// The ready index for `key`, if one has been built.
    pub fn get(&self, key: &SurfaceKey) -> Option<Arc<FrontierIndex>> {
        match self.slots.lock().unwrap().get(key) {
            Some(SlotState::Ready(idx)) => Some(idx.clone()),
            _ => None,
        }
    }

    /// Return the index for `key`, building it at most once across all
    /// concurrent callers.  The second tuple element is true for the
    /// caller that actually built (so it can byte-account the surface).
    pub fn get_or_build(
        &self,
        key: SurfaceKey,
        build: impl FnOnce() -> Result<FrontierIndex>,
    ) -> Result<(Arc<FrontierIndex>, bool)> {
        let mut slots = self.slots.lock().unwrap();
        loop {
            match slots.get(&key) {
                Some(SlotState::Ready(idx)) => return Ok((idx.clone(), false)),
                Some(SlotState::Building) => slots = self.ready.wait(slots).unwrap(),
                None => {
                    slots.insert(key, SlotState::Building);
                    break;
                }
            }
        }
        drop(slots);
        let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(build))
            .unwrap_or_else(|_| Err(anyhow!("frontier build panicked")));
        let mut slots = self.slots.lock().unwrap();
        match built {
            Ok(idx) => {
                let idx = Arc::new(idx);
                slots.insert(key, SlotState::Ready(idx.clone()));
                self.ready.notify_all();
                Ok((idx, true))
            }
            Err(e) => {
                slots.remove(&key);
                self.ready.notify_all();
                Err(e)
            }
        }
    }

    /// Total approximate bytes across all ready surfaces.
    pub fn bytes(&self) -> usize {
        self.slots
            .lock()
            .unwrap()
            .values()
            .map(|s| match s {
                SlotState::Ready(idx) => idx.bytes(),
                SlotState::Building => 0,
            })
            .sum()
    }

    /// Snapshot of every ready surface, deterministically ordered.
    pub fn surfaces(&self) -> Vec<(SurfaceKey, Arc<FrontierIndex>)> {
        let mut out: Vec<(SurfaceKey, Arc<FrontierIndex>)> = self
            .slots
            .lock()
            .unwrap()
            .iter()
            .filter_map(|(k, s)| match s {
                SlotState::Ready(idx) => Some((*k, idx.clone())),
                SlotState::Building => None,
            })
            .collect();
        out.sort_by_key(|(k, _)| *k);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::testutil::random_problem;
    use crate::util::rng::Rng;

    fn surface_for(p: &MpqProblem, steps: usize) -> FrontierSurface {
        FrontierBuilder::new(steps).build(p).unwrap()
    }

    #[test]
    fn builder_rejects_degenerate_input() {
        assert!(FrontierBuilder::new(1).build(&MpqProblem::default()).is_err());
        assert!(FrontierBuilder::new(8).build(&MpqProblem::default()).is_err());
    }

    #[test]
    fn vertices_are_mutually_non_dominated() {
        let mut rng = Rng::new(11);
        let p = random_problem(&mut rng, 5, 4, 0.5);
        let s = surface_for(&p, 16);
        assert!(s.n_vertices() >= 2, "expected a non-trivial frontier");
        for (i, a) in s.vertices().iter().enumerate() {
            for (j, b) in s.vertices().iter().enumerate() {
                if i != j {
                    assert!(
                        !(a.dominates_or_ties(b)),
                        "vertex {i} dominates vertex {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn lower_bound_never_exceeds_brute_force() {
        let mut rng = Rng::new(7);
        for _ in 0..5 {
            let p = random_problem(&mut rng, 4, 3, 0.4);
            let s = surface_for(&p, 12);
            let opt = p.brute_force().unwrap();
            let lb = s.lower_bound(p.bitops_cap, None);
            assert!(
                lb <= opt.cost + 1e-9,
                "dual bound {lb} above brute-force optimum {}",
                opt.cost
            );
        }
    }

    #[test]
    fn loose_tolerance_hits_and_answers_feasibly() {
        let mut rng = Rng::new(3);
        let p = random_problem(&mut rng, 5, 4, 0.6);
        let idx = FrontierIndex::new(surface_for(&p, 24), 10.0);
        let hit = idx.query(p.bitops_cap, None).expect("loose tolerance must hit");
        assert!(hit.bitops <= p.bitops_cap.unwrap());
        let opt = p.brute_force().unwrap();
        assert!(hit.cost >= opt.cost - 1e-9, "frontier beat brute force");
        assert_eq!(idx.stats().hits, 1);
    }

    #[test]
    fn zero_tolerance_misses_then_refined_repeat_hits_exactly() {
        let mut rng = Rng::new(19);
        let p = random_problem(&mut rng, 4, 3, 0.5);
        let idx = FrontierIndex::new(surface_for(&p, 8), 0.0);
        let cap = p.bitops_cap;
        // Dual certificates are rarely exactly tight → expect a miss.
        if idx.query(cap, None).is_some() {
            return; // grid happened to certify exactly; nothing to refine
        }
        let opt = p.brute_force().unwrap();
        let policy = p.to_bit_config(&opt);
        idx.refine(cap, None, policy.clone(), opt.cost, opt.bitops, opt.size_bits, true);
        let hit = idx.query(cap, None).expect("refined cap pair must hit");
        assert_eq!(hit.policy, policy);
        assert_eq!(hit.cost, opt.cost);
        assert_eq!(hit.gap, 0.0);
        let st = idx.stats();
        assert_eq!((st.hits, st.misses, st.refines, st.bounds), (1, 1, 1, 1));
    }

    #[test]
    fn dual_cap_queries_respect_both_axes() {
        let mut rng = Rng::new(23);
        let p = random_problem(&mut rng, 5, 4, 0.7);
        let idx = FrontierIndex::new(surface_for(&p, 16), 10.0);
        // A size cap midway between the min and max size of the sweep.
        let sizes: Vec<u64> = {
            let min: u64 = p.groups.iter().map(|l| l.iter().map(|o| o.size_bits).min().unwrap()).sum();
            let max: u64 = p.groups.iter().map(|l| l.iter().map(|o| o.size_bits).max().unwrap()).sum();
            vec![min + (max - min) / 2]
        };
        let hit = idx.query(p.bitops_cap, Some(sizes[0]));
        if let Some(h) = hit {
            assert!(h.bitops <= p.bitops_cap.unwrap());
            assert!(h.size_bits <= sizes[0]);
        }
        // Impossible caps must miss rather than serve an infeasible vertex.
        assert!(idx.query(Some(0), Some(0)).is_none());
    }

    #[test]
    fn surface_key_collapses_signed_zero() {
        use crate::search::Granularity;
        let g = Granularity::Layer;
        assert_eq!(SurfaceKey::new(0.0, false, g), SurfaceKey::new(-0.0, false, g));
        assert_ne!(SurfaceKey::new(1.0, false, g), SurfaceKey::new(1.0, true, g));
    }

    #[test]
    fn surface_key_splits_by_granularity() {
        use crate::search::Granularity;
        let layer = SurfaceKey::new(1.0, false, Granularity::Layer);
        let chan = SurfaceKey::new(1.0, false, Granularity::ChannelGroup(8));
        let kern = SurfaceKey::new(1.0, false, Granularity::Kernel);
        assert_ne!(layer, chan);
        assert_ne!(layer, kern);
        assert_ne!(chan, kern);
        assert_eq!(chan, SurfaceKey::new(1.0, false, Granularity::ChannelGroup(8)));
        assert_ne!(chan, SurfaceKey::new(1.0, false, Granularity::ChannelGroup(4)));
        assert_eq!(chan.granularity(), Granularity::ChannelGroup(8));
    }

    #[test]
    fn set_single_flights_concurrent_builds() {
        let set = Arc::new(FrontierSet::new());
        let builds = Arc::new(AtomicUsize::new(0));
        let mut rng = Rng::new(5);
        let p = Arc::new(random_problem(&mut rng, 4, 3, 0.5));
        let key = SurfaceKey::new(1.0, false, crate::search::Granularity::Layer);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let (set, builds, p) = (set.clone(), builds.clone(), p.clone());
                std::thread::spawn(move || {
                    set.get_or_build(key, || {
                        builds.fetch_add(1, Ordering::Relaxed);
                        Ok(FrontierIndex::new(FrontierBuilder::new(8).build(&p)?, 0.1))
                    })
                    .unwrap()
                    .1
                })
            })
            .collect();
        let built_flags: Vec<bool> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(builds.load(Ordering::Relaxed), 1, "build must run exactly once");
        assert_eq!(built_flags.iter().filter(|b| **b).count(), 1);
        assert!(set.bytes() > 0);
        assert_eq!(set.surfaces().len(), 1);
    }

    #[test]
    fn failed_build_clears_the_slot_for_retry() {
        let set = FrontierSet::new();
        let key = SurfaceKey::new(2.0, true, crate::search::Granularity::Layer);
        assert!(set.get_or_build(key, || bail!("nope")).is_err());
        assert!(set.get(&key).is_none());
        let mut rng = Rng::new(9);
        let p = random_problem(&mut rng, 3, 3, 0.5);
        let (_, built) = set
            .get_or_build(key, || Ok(FrontierIndex::new(FrontierBuilder::new(4).build(&p)?, 0.1)))
            .unwrap();
        assert!(built);
    }
}
