//! Integer-arithmetic deployment simulator.
//!
//! The AOT artifacts run *fake*-quantization (float ops on quantized
//! values) — standard for QAT.  Deployment executes with real integer
//! arithmetic.  This module closes that loop for the dense path: it packs
//! a searched policy into actual `i8`/`u8` tensors, runs the GEMMs in
//! `i32` accumulation, and dequantizes per layer, so a policy can be
//! *validated as deployable* and its true integer-domain accuracy checked
//! against the fake-quant path (they agree exactly when the fake-quant
//! rounding grid matches — asserted in tests and used by
//! `pjrt_int_infer` integration coverage).
//!
//! Scope: dense (MLP-shaped) networks — enough to demonstrate the
//! equivalence; conv deployment would follow the same recipe per channel.

use anyhow::{bail, ensure, Result};

use crate::kernels::gemm::{gemm_i8, PackedI8};
use crate::kernels::pool::WorkerPool;
use crate::kernels::scratch::{with_thread_scratch, ScratchArena};
use crate::models::ModelMeta;
use crate::quant::{act_bounds, quantize_codes_into, weight_bounds, BitConfig};
use crate::tensor::{argmax_total, relu_inplace};

/// One dense layer packed for integer execution.
#[derive(Debug, Clone)]
pub struct IntDense {
    pub name: String,
    /// Quantized weights, row-major [in, out], stored as i32 codes
    /// (range fits the layer's w_bits).
    pub wq: Vec<i32>,
    /// The same codes pre-transposed/packed `[out, in]` once at pack time
    /// **and narrowed to `i8`** (every supported bit-width fits), so the
    /// GEMM inner loop is unit-stride over a weight stream 4x denser in
    /// cache than the `i32` codes (`kernels::gemm::PackedI8`).
    pub wt: PackedI8,
    pub in_f: usize,
    pub out_f: usize,
    pub bias: Vec<f32>,
    pub s_w: f32,
    pub s_a: f32,
    pub a_qmin: f32,
    pub a_qmax: f32,
}

/// A packed integer model: sequence of dense layers with ReLU between.
#[derive(Debug, Clone)]
pub struct IntModel {
    pub layers: Vec<IntDense>,
    pub n_classes: usize,
}

impl IntModel {
    /// Pack a flat parameter buffer + policy + per-layer scales.
    ///
    /// Requires every quantized layer to be "dense" kind with a matching
    /// `<name>.w` / `<name>.b` parameter pair (the MLP layout).
    pub fn pack(meta: &ModelMeta, flat: &[f32], policy: &BitConfig, sw: &[f32], sa: &[f32]) -> Result<IntModel> {
        ensure!(flat.len() == meta.param_size, "param size mismatch");
        policy.validate(meta)?;
        let mut layers = Vec::new();
        for q in &meta.qlayers {
            if q.kind != "dense" {
                bail!("IntModel supports dense layers only; {} is {}", q.name, q.kind);
            }
            let wp = meta
                .params
                .iter()
                .find(|p| p.name == format!("{}.w", q.name))
                .ok_or_else(|| anyhow::anyhow!("{}: missing weight param", q.name))?;
            let bp = meta
                .params
                .iter()
                .find(|p| p.name == format!("{}.b", q.name))
                .ok_or_else(|| anyhow::anyhow!("{}: missing bias param", q.name))?;
            ensure!(wp.shape.len() == 2, "{}: weight must be 2-D", q.name);
            let (in_f, out_f) = (wp.shape[0], wp.shape[1]);
            // PackedI8 narrows codes to i8; weight_bounds(8) = [-128, 127]
            // fits exactly, anything wider must be a recoverable error
            // (pack is the fallible API — from_row_major just asserts).
            ensure!(
                policy.w_bits[q.index] <= 8,
                "{}: w_bits {} exceeds the 8-bit limit of i8 code packing",
                q.name,
                policy.w_bits[q.index]
            );
            let (wmin, wmax) = weight_bounds(policy.w_bits[q.index]);
            let (amin, amax) = act_bounds(policy.a_bits[q.index]);
            let s_w = sw[q.index].max(1e-9);
            let w = &flat[wp.offset..wp.offset + wp.size];
            let wq: Vec<i32> = w
                .iter()
                .map(|&v| (v / s_w).clamp(wmin, wmax).round_ties_even() as i32)
                .collect();
            let wt = PackedI8::from_row_major(&wq, in_f, out_f);
            layers.push(IntDense {
                name: q.name.clone(),
                wq,
                wt,
                in_f,
                out_f,
                bias: flat[bp.offset..bp.offset + bp.size].to_vec(),
                s_w,
                s_a: sa[q.index].max(1e-9),
                a_qmin: amin,
                a_qmax: amax,
            });
        }
        Ok(IntModel { layers, n_classes: meta.n_classes })
    }

    /// Integer model size in bytes (codes at their true bit-width).
    pub fn packed_bits(&self, policy: &BitConfig) -> u64 {
        self.layers
            .iter()
            .zip(&policy.w_bits)
            .map(|(l, &b)| l.wq.len() as u64 * b as u64)
            .sum()
    }

    /// Forward one batch of flattened inputs [b, in_f0] -> logits.
    ///
    /// Activations quantize to unsigned codes, weights are signed codes,
    /// the GEMM accumulates in i64 (provably no overflow for the sizes
    /// here), and each layer dequantizes by `s_a * s_w`.
    ///
    /// Runs the packed/blocked `kernels::gemm` path, sharded over batch
    /// rows on the global worker pool; integer accumulation is exact, so
    /// logits are bit-identical to the naive single-thread loop at any
    /// thread count (pinned by tests).
    pub fn forward(&self, x: &[f32], batch: usize) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.forward_into(x, batch, &mut out)?;
        Ok(out)
    }

    /// [`IntModel::forward`] into a caller-reused logits buffer; all
    /// intermediates come from the per-thread scratch arena, so the
    /// steady-state forward allocates nothing.
    pub fn forward_into(&self, x: &[f32], batch: usize, out: &mut Vec<f32>) -> Result<()> {
        self.forward_pooled(x, batch, out, &WorkerPool::global())
    }

    /// [`IntModel::forward_into`] on an explicit pool (the 1-vs-N
    /// determinism tests and benches pin thread counts through this).
    pub fn forward_pooled(
        &self,
        x: &[f32],
        batch: usize,
        out: &mut Vec<f32>,
        pool: &WorkerPool,
    ) -> Result<()> {
        with_thread_scratch(|scratch| self.forward_scratch(x, batch, out, scratch, pool))
    }

    fn forward_scratch(
        &self,
        x: &[f32],
        batch: usize,
        out: &mut Vec<f32>,
        scratch: &mut ScratchArena,
        pool: &WorkerPool,
    ) -> Result<()> {
        let mut act = scratch.take_f32(x.len());
        act.copy_from_slice(x);
        let mut next = scratch.take_f32(0);
        let mut codes = scratch.take_i64(0);
        let mut acc = scratch.take_i64(0);
        let mut status = Ok(());
        for (li, l) in self.layers.iter().enumerate() {
            if act.len() != batch * l.in_f {
                status = Err(anyhow::anyhow!("{}: input size mismatch", l.name));
                break;
            }
            // quantize the activation buffer to integer codes
            quantize_codes_into(&act, l.s_a, l.a_qmin, l.a_qmax, &mut codes);
            acc.clear();
            acc.resize(batch * l.out_f, 0);
            gemm_i8(&codes, batch, &l.wt, &mut acc, pool);
            next.clear();
            next.resize(batch * l.out_f, 0.0);
            for b in 0..batch {
                for o in 0..l.out_f {
                    next[b * l.out_f + o] =
                        acc[b * l.out_f + o] as f32 * l.s_a * l.s_w + l.bias[o];
                }
            }
            // hidden layers are ReLU'd (MLP layout); final layer is logits
            if li + 1 < self.layers.len() {
                relu_inplace(&mut next);
            }
            std::mem::swap(&mut act, &mut next);
        }
        if status.is_ok() {
            out.clear();
            out.extend_from_slice(&act);
        }
        scratch.put_f32(act);
        scratch.put_f32(next);
        scratch.put_i64(codes);
        scratch.put_i64(acc);
        status
    }

    /// Top-1 accuracy over a dataset of flattened inputs.
    ///
    /// Argmax is a NaN-safe total-order fold ([`argmax_total`]): a NaN
    /// logit can never win or panic (the old `partial_cmp().unwrap()`
    /// aborted the whole evaluation on the first NaN).
    pub fn accuracy(&self, x: &[f32], y: &[i32], batch: usize) -> Result<f64> {
        let n = y.len();
        let feat = x.len() / n;
        let mut correct = 0usize;
        let mut logits = Vec::new();
        let mut i = 0;
        while i < n {
            let b = batch.min(n - i);
            self.forward_into(&x[i * feat..(i + b) * feat], b, &mut logits)?;
            for bi in 0..b {
                let row = &logits[bi * self.n_classes..(bi + 1) * self.n_classes];
                if argmax_total(row) as i32 == y[i + bi] {
                    correct += 1;
                }
            }
            i += b;
        }
        Ok(correct as f64 / n as f64)
    }
}

/// Reference float fake-quant forward for the same MLP layout — used to
/// assert int-domain == fake-quant-domain equivalence.
///
/// Accumulation stays f64 in ascending-`i` order (the reference
/// semantics), but the weight reads go through the packed transposed
/// codes and every intermediate comes from the scratch arena — no per
/// row/batch allocation.
pub fn fake_quant_forward_ref(m: &IntModel, x: &[f32], batch: usize) -> Result<Vec<f32>> {
    with_thread_scratch(|scratch| {
        let mut act = scratch.take_f32(x.len());
        act.copy_from_slice(x);
        let mut aq = scratch.take_f32(0);
        let mut next = scratch.take_f32(0);
        for (li, l) in m.layers.iter().enumerate() {
            // fake-quantize the activation buffer
            aq.clear();
            aq.extend(
                act.iter()
                    .map(|&v| (v / l.s_a).clamp(l.a_qmin, l.a_qmax).round_ties_even() * l.s_a),
            );
            next.clear();
            next.resize(batch * l.out_f, 0.0);
            for b in 0..batch {
                let row = &aq[b * l.in_f..(b + 1) * l.in_f];
                for o in 0..l.out_f {
                    let wr = l.wt.row(o);
                    let mut acc = 0.0f64;
                    for i in 0..l.in_f {
                        acc += row[i] as f64 * (wr[i] as f32 * l.s_w) as f64;
                    }
                    next[b * l.out_f + o] = acc as f32 + l.bias[o];
                }
            }
            if li + 1 < m.layers.len() {
                relu_inplace(&mut next);
            }
            std::mem::swap(&mut act, &mut next);
        }
        let out = act.clone();
        scratch.put_f32(act);
        scratch.put_f32(aq);
        scratch.put_f32(next);
        Ok(out)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use crate::util::rng::Rng;
    use std::path::Path;

    fn mlp_meta() -> ModelMeta {
        // 2-layer MLP: 6 -> 5 -> 3
        let text = r#"{"name":"tinymlp","param_size":53,"n_qlayers":2,
          "input_shape":[6],"n_classes":3,
          "train_batch":4,"eval_batch":8,"serve_batch":2,
          "bit_options":[2,3,4,5,6],"pin_bits":8,
          "params":[
            {"name":"fc1.w","shape":[6,5],"offset":0,"size":30,"init":"he_dense","fan_in":6},
            {"name":"fc1.b","shape":[5],"offset":30,"size":5,"init":"zeros","fan_in":6},
            {"name":"fc2.w","shape":[5,3],"offset":35,"size":15,"init":"he_dense","fan_in":5},
            {"name":"fc2.b","shape":[3],"offset":50,"size":3,"init":"zeros","fan_in":5}],
          "qlayers":[
            {"index":0,"name":"fc1","kind":"dense","macs":30,"w_numel":30,"pinned":false},
            {"index":1,"name":"fc2","kind":"dense","macs":15,"w_numel":15,"pinned":false}],
          "artifacts":{}}"#;
        ModelMeta::from_json(&Json::parse(text).unwrap(), Path::new("/tmp")).unwrap()
    }

    fn setup() -> (ModelMeta, Vec<f32>, BitConfig, Vec<f32>, Vec<f32>) {
        let meta = mlp_meta();
        let mut rng = Rng::new(5);
        let flat = meta.init_params(&mut rng);
        let policy = BitConfig { w_bits: vec![4, 3], a_bits: vec![4, 5] };
        (meta, flat, policy, vec![0.07, 0.05], vec![0.06, 0.08])
    }

    #[test]
    fn int_equals_fake_quant_path() {
        let (meta, flat, policy, sw, sa) = setup();
        let m = IntModel::pack(&meta, &flat, &policy, &sw, &sa).unwrap();
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..4 * 6).map(|_| rng.f32()).collect();
        let int_out = m.forward(&x, 4).unwrap();
        let fq_out = fake_quant_forward_ref(&m, &x, 4).unwrap();
        for (a, b) in int_out.iter().zip(&fq_out) {
            assert!((a - b).abs() < 1e-4, "int {a} vs fq {b}");
        }
    }

    #[test]
    fn pack_rejects_bit_widths_beyond_i8_with_an_error() {
        // A pinned 16-bit layer passes BitConfig::validate (pin_bits is an
        // arbitrary u8), so pack() must reject it as a recoverable error —
        // not hit the assert inside PackedI8::from_row_major.
        let text = r#"{"name":"widemlp","param_size":53,"n_qlayers":2,
          "input_shape":[6],"n_classes":3,
          "train_batch":4,"eval_batch":8,"serve_batch":2,
          "bit_options":[2,3,4,5,6],"pin_bits":16,
          "params":[
            {"name":"fc1.w","shape":[6,5],"offset":0,"size":30,"init":"he_dense","fan_in":6},
            {"name":"fc1.b","shape":[5],"offset":30,"size":5,"init":"zeros","fan_in":6},
            {"name":"fc2.w","shape":[5,3],"offset":35,"size":15,"init":"he_dense","fan_in":5},
            {"name":"fc2.b","shape":[3],"offset":50,"size":3,"init":"zeros","fan_in":5}],
          "qlayers":[
            {"index":0,"name":"fc1","kind":"dense","macs":30,"w_numel":30,"pinned":true},
            {"index":1,"name":"fc2","kind":"dense","macs":15,"w_numel":15,"pinned":false}],
          "artifacts":{}}"#;
        let meta = ModelMeta::from_json(&Json::parse(text).unwrap(), Path::new("/tmp")).unwrap();
        let mut rng = Rng::new(5);
        let flat = meta.init_params(&mut rng);
        let policy = BitConfig { w_bits: vec![16, 4], a_bits: vec![16, 4] };
        policy.validate(&meta).unwrap();
        let err = IntModel::pack(&meta, &flat, &policy, &[0.07, 0.05], &[0.06, 0.08])
            .expect_err("16-bit codes cannot pack to i8");
        assert!(format!("{err:#}").contains("8-bit limit"), "{err:#}");
    }

    #[test]
    fn codes_respect_bit_range() {
        let (meta, flat, policy, sw, sa) = setup();
        let m = IntModel::pack(&meta, &flat, &policy, &sw, &sa).unwrap();
        // fc1 at 4 bits: codes in [-8, 7]
        assert!(m.layers[0].wq.iter().all(|&c| (-8..=7).contains(&c)));
        // fc2 at 3 bits: codes in [-4, 3]
        assert!(m.layers[1].wq.iter().all(|&c| (-4..=3).contains(&c)));
    }

    #[test]
    fn packed_size_matches_cost_model() {
        let (meta, flat, policy, sw, sa) = setup();
        let m = IntModel::pack(&meta, &flat, &policy, &sw, &sa).unwrap();
        let bits = m.packed_bits(&policy);
        assert_eq!(bits, 30 * 4 + 15 * 3);
        // cost model rounds up to whole bytes
        assert_eq!(crate::quant::cost::model_size_bytes(&meta, &policy), bits.div_ceil(8));
    }

    #[test]
    fn accuracy_runs() {
        let (meta, flat, policy, sw, sa) = setup();
        let m = IntModel::pack(&meta, &flat, &policy, &sw, &sa).unwrap();
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..20 * 6).map(|_| rng.f32()).collect();
        let y: Vec<i32> = (0..20).map(|i| (i % 3) as i32).collect();
        let acc = m.accuracy(&x, &y, 8).unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }

    /// The pre-PR scalar forward, replicated verbatim: per-row code Vec,
    /// weight reads striding by `out_f`.  The kernel path must match it
    /// bit-for-bit.
    fn forward_naive_ref(m: &IntModel, x: &[f32], batch: usize) -> Vec<f32> {
        let mut act = x.to_vec();
        for (li, l) in m.layers.iter().enumerate() {
            let mut out = vec![0.0f32; batch * l.out_f];
            for b in 0..batch {
                let row = &act[b * l.in_f..(b + 1) * l.in_f];
                let codes: Vec<i64> = row
                    .iter()
                    .map(|&v| (v / l.s_a).clamp(l.a_qmin, l.a_qmax).round_ties_even() as i64)
                    .collect();
                for o in 0..l.out_f {
                    let mut acc: i64 = 0;
                    for i in 0..l.in_f {
                        acc += codes[i] * l.wq[i * l.out_f + o] as i64;
                    }
                    out[b * l.out_f + o] = acc as f32 * l.s_a * l.s_w + l.bias[o];
                }
            }
            if li + 1 < m.layers.len() {
                for v in out.iter_mut() {
                    *v = v.max(0.0);
                }
            }
            act = out;
        }
        act
    }

    #[test]
    fn kernel_forward_bit_identical_to_naive_and_thread_invariant() {
        let (meta, flat, policy, sw, sa) = setup();
        let m = IntModel::pack(&meta, &flat, &policy, &sw, &sa).unwrap();
        let mut rng = Rng::new(21);
        let x: Vec<f32> = (0..16 * 6).map(|_| rng.f32()).collect();
        let reference = forward_naive_ref(&m, &x, 16);
        for threads in [1usize, 4] {
            let mut logits = Vec::new();
            m.forward_pooled(&x, 16, &mut logits, &crate::kernels::WorkerPool::new(threads))
                .unwrap();
            // integer accumulation is exact: bitwise equality, any threads
            assert_eq!(logits, reference, "{threads} threads");
        }
    }

    #[test]
    fn accuracy_survives_nan_logits() {
        let (meta, flat, policy, sw, sa) = setup();
        let mut m = IntModel::pack(&meta, &flat, &policy, &sw, &sa).unwrap();
        // Poison the final layer's bias: every logit row becomes NaN-laden.
        let last = m.layers.len() - 1;
        m.layers[last].bias[0] = f32::NAN;
        let mut rng = Rng::new(13);
        let x: Vec<f32> = (0..10 * 6).map(|_| rng.f32()).collect();
        let y: Vec<i32> = (0..10).map(|i| (i % 3) as i32).collect();
        // pre-PR argmax panicked here; now NaN simply never wins
        let acc = m.accuracy(&x, &y, 4).unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }

    #[test]
    fn forward_into_reuses_caller_buffer() {
        let (meta, flat, policy, sw, sa) = setup();
        let m = IntModel::pack(&meta, &flat, &policy, &sw, &sa).unwrap();
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..4 * 6).map(|_| rng.f32()).collect();
        let mut out = Vec::new();
        m.forward_into(&x, 4, &mut out).unwrap();
        assert_eq!(out.len(), 4 * 3);
        let cap = out.capacity();
        m.forward_into(&x, 4, &mut out).unwrap();
        assert_eq!(out.capacity(), cap, "steady-state forward must not reallocate");
        assert_eq!(out, m.forward(&x, 4).unwrap());
    }

    #[test]
    fn rejects_conv_layers() {
        let mut meta = mlp_meta();
        meta.qlayers[0].kind = "conv".into();
        let flat = vec![0.0; meta.param_size];
        let policy = BitConfig { w_bits: vec![4, 4], a_bits: vec![4, 4] };
        assert!(IntModel::pack(&meta, &flat, &policy, &[0.1, 0.1], &[0.1, 0.1]).is_err());
    }

    #[test]
    fn higher_bits_closer_to_float() {
        let (meta, flat, _, sw, sa) = setup();
        let mut rng = Rng::new(11);
        let x: Vec<f32> = (0..8 * 6).map(|_| rng.f32()).collect();
        // float reference: effectively-unquantized via wide codes
        let wide = BitConfig { w_bits: vec![6, 6], a_bits: vec![6, 6] };
        let narrow = BitConfig { w_bits: vec![2, 2], a_bits: vec![2, 2] };
        let m_wide = IntModel::pack(&meta, &flat, &wide, &sw, &sa).unwrap();
        let m_narrow = IntModel::pack(&meta, &flat, &narrow, &sw, &sa).unwrap();
        // pure-float reference forward (no quantization at all)
        let fwd_float = |x: &[f32]| -> Vec<f32> {
            let mut act = x.to_vec();
            for (li, (wp, bp)) in [(0usize, 1usize), (2, 3)].iter().enumerate() {
                let w = &flat[meta.params[*wp].offset..meta.params[*wp].offset + meta.params[*wp].size];
                let bias = &flat[meta.params[*bp].offset..meta.params[*bp].offset + meta.params[*bp].size];
                let (in_f, out_f) = (meta.params[*wp].shape[0], meta.params[*wp].shape[1]);
                let batch = act.len() / in_f;
                let mut out = vec![0.0f32; batch * out_f];
                for b in 0..batch {
                    for o in 0..out_f {
                        let mut acc = 0.0f32;
                        for i in 0..in_f {
                            acc += act[b * in_f + i] * w[i * out_f + o];
                        }
                        out[b * out_f + o] = acc + bias[o];
                    }
                }
                if li == 0 {
                    for v in out.iter_mut() { *v = v.max(0.0); }
                }
                act = out;
            }
            act
        };
        let r = fwd_float(&x);
        let dw: f32 = m_wide.forward(&x, 8).unwrap().iter().zip(&r).map(|(a, b)| (a - b).abs()).sum();
        let dn: f32 = m_narrow.forward(&x, 8).unwrap().iter().zip(&r).map(|(a, b)| (a - b).abs()).sum();
        assert!(dw < dn, "wide {dw} should beat narrow {dn}");
    }
}
