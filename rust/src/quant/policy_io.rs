//! MPQ policy (de)serialization — the deployment artifact.
//!
//! A searched policy is the *product* of this whole system: a per-layer
//! (w_bits, a_bits) assignment plus provenance (model, constraint, cost).
//! This module defines the JSON wire format the CLI emits
//! (`limpq search --save`), the fleet server speaks, and downstream
//! deployment tooling would consume.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::models::ModelMeta;
use crate::quant::BitConfig;
use crate::util::json::Json;

/// A policy plus provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyFile {
    pub model: String,
    pub policy: BitConfig,
    pub layer_names: Vec<String>,
    pub bitops: u64,
    pub size_bits: u64,
    pub objective: f64,
    pub alpha: f64,
}

impl PolicyFile {
    pub fn new(
        meta: &ModelMeta,
        policy: BitConfig,
        bitops: u64,
        size_bits: u64,
        objective: f64,
        alpha: f64,
    ) -> PolicyFile {
        PolicyFile {
            model: meta.name.clone(),
            layer_names: meta.qlayers.iter().map(|q| q.name.clone()).collect(),
            policy,
            bitops,
            size_bits,
            objective,
            alpha,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::from("limpq-policy-v1")),
            ("model", Json::from(self.model.as_str())),
            ("layers", Json::Arr(self.layer_names.iter().map(|n| Json::from(n.as_str())).collect())),
            ("w_bits", Json::arr_usize(&self.policy.w_bits.iter().map(|&b| b as usize).collect::<Vec<_>>())),
            ("a_bits", Json::arr_usize(&self.policy.a_bits.iter().map(|&b| b as usize).collect::<Vec<_>>())),
            ("bitops", Json::Num(self.bitops as f64)),
            ("size_bits", Json::Num(self.size_bits as f64)),
            ("objective", Json::Num(self.objective)),
            ("alpha", Json::Num(self.alpha)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<PolicyFile> {
        ensure!(
            j.get("format")?.as_str()? == "limpq-policy-v1",
            "unknown policy format {:?}",
            j.get("format")?
        );
        let w_bits: Vec<u8> = j.get("w_bits")?.usize_vec()?.into_iter().map(|b| b as u8).collect();
        let a_bits: Vec<u8> = j.get("a_bits")?.usize_vec()?.into_iter().map(|b| b as u8).collect();
        ensure!(w_bits.len() == a_bits.len(), "w/a length mismatch");
        let layer_names = j
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        ensure!(layer_names.len() == w_bits.len(), "layer-name count mismatch");
        Ok(PolicyFile {
            model: j.get("model")?.as_str()?.to_string(),
            policy: BitConfig { w_bits, a_bits },
            layer_names,
            bitops: j.get("bitops")?.as_f64()? as u64,
            size_bits: j.get("size_bits")?.as_f64()? as u64,
            objective: j.get("objective")?.as_f64()?,
            alpha: j.get("alpha")?.as_f64()?,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string()).with_context(|| format!("write {path:?}"))
    }

    pub fn load(path: &Path) -> Result<PolicyFile> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Validate against a model's metadata before deployment.
    pub fn check_against(&self, meta: &ModelMeta) -> Result<()> {
        ensure!(self.model == meta.name, "policy for {:?}, model is {:?}", self.model, meta.name);
        ensure!(self.policy.len() == meta.n_qlayers, "layer count mismatch");
        for (i, q) in meta.qlayers.iter().enumerate() {
            ensure!(self.layer_names[i] == q.name, "layer {} name mismatch", i);
        }
        self.policy.validate(meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn meta() -> ModelMeta {
        let text = r#"{"name":"m","param_size":10,"n_qlayers":2,
          "input_shape":[2,2,1],"n_classes":2,
          "train_batch":4,"eval_batch":8,"serve_batch":2,
          "bit_options":[2,3,4,5,6],"pin_bits":8,
          "params":[{"name":"a.w","shape":[10],"offset":0,"size":10,"init":"zeros","fan_in":1}],
          "qlayers":[
            {"index":0,"name":"a","kind":"conv","macs":10,"w_numel":10,"pinned":true},
            {"index":1,"name":"b","kind":"conv","macs":10,"w_numel":10,"pinned":true}],
          "artifacts":{}}"#;
        ModelMeta::from_json(&Json::parse(text).unwrap(), Path::new("/tmp")).unwrap()
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("limpq_pol_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip() {
        let m = meta();
        let pf = PolicyFile::new(
            &m,
            BitConfig { w_bits: vec![8, 8], a_bits: vec![8, 8] },
            1280,
            160,
            0.25,
            3.0,
        );
        let p = tmp("rt.json");
        pf.save(&p).unwrap();
        let loaded = PolicyFile::load(&p).unwrap();
        assert_eq!(loaded, pf);
        loaded.check_against(&m).unwrap();
    }

    #[test]
    fn rejects_wrong_model() {
        let m = meta();
        let mut pf = PolicyFile::new(
            &m,
            BitConfig { w_bits: vec![8, 8], a_bits: vec![8, 8] },
            0,
            0,
            0.0,
            1.0,
        );
        pf.model = "other".into();
        assert!(pf.check_against(&m).is_err());
    }

    #[test]
    fn rejects_bad_format() {
        let j = Json::parse(r#"{"format":"nope"}"#).unwrap();
        assert!(PolicyFile::from_json(&j).is_err());
    }

    #[test]
    fn rejects_pin_violation() {
        let m = meta();
        let pf = PolicyFile::new(
            &m,
            BitConfig { w_bits: vec![4, 8], a_bits: vec![8, 8] }, // layer 0 pinned to 8
            0,
            0,
            0.0,
            1.0,
        );
        assert!(pf.check_against(&m).is_err());
    }
}
