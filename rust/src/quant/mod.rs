//! Quantization host math: bit-width bookkeeping, clip bounds, scale
//! initialization, and the reference fake-quantizer used by unit tests.
//!
//! The quantizer semantics mirror `python/compile/kernels/ref.py`
//! (LSQ, paper eq. 1): weights symmetric signed, activations unsigned.

pub mod cost;
pub mod int_infer;
pub mod policy_io;

use anyhow::{bail, Result};

use crate::models::ModelMeta;
use crate::tensor::mean_abs;

/// The effective "off" qmax: ~2^23 keeps round(v/s) exact in f32, so a
/// layer quantized with this bound behaves like a full-precision layer
/// (used by the Fig.1 solo-quantization contrast experiment).
pub const QMAX_OFF: f32 = 8_388_607.0;

/// Clip bounds for a weight quantizer at `bits` (symmetric signed).
pub fn weight_bounds(bits: u8) -> (f32, f32) {
    let qmax = ((1u32 << (bits - 1)) - 1) as f32;
    (-(qmax + 1.0), qmax)
}

/// Clip bounds for an activation quantizer at `bits` (unsigned).
pub fn act_bounds(bits: u8) -> (f32, f32) {
    (0.0, ((1u32 << bits) - 1) as f32)
}

/// qmax for weights at `bits`.
pub fn weight_qmax(bits: u8) -> f32 {
    weight_bounds(bits).1
}

/// qmax for activations at `bits`.
pub fn act_qmax(bits: u8) -> f32 {
    act_bounds(bits).1
}

/// A full per-layer bit assignment (the MPQ policy "S" of the paper).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitConfig {
    pub w_bits: Vec<u8>,
    pub a_bits: Vec<u8>,
}

impl BitConfig {
    pub fn uniform(n_layers: usize, w: u8, a: u8) -> BitConfig {
        BitConfig { w_bits: vec![w; n_layers], a_bits: vec![a; n_layers] }
    }

    /// Uniform config with first/last pinned to `pin_bits`.
    pub fn uniform_pinned(meta: &ModelMeta, w: u8, a: u8) -> BitConfig {
        let mut c = Self::uniform(meta.n_qlayers, w, a);
        c.apply_pins(meta);
        c
    }

    pub fn apply_pins(&mut self, meta: &ModelMeta) {
        for q in &meta.qlayers {
            if q.pinned {
                self.w_bits[q.index] = meta.pin_bits;
                self.a_bits[q.index] = meta.pin_bits;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.w_bits.len()
    }

    pub fn is_empty(&self) -> bool {
        self.w_bits.is_empty()
    }

    /// Per-layer qmax vectors — the runtime inputs carrying the bit-widths
    /// into the static HLO (DESIGN.md §3).
    pub fn qmax_vectors(&self) -> (Vec<f32>, Vec<f32>) {
        (
            self.w_bits.iter().map(|&b| weight_qmax(b)).collect(),
            self.a_bits.iter().map(|&b| act_qmax(b)).collect(),
        )
    }

    pub fn validate(&self, meta: &ModelMeta) -> Result<()> {
        if self.w_bits.len() != meta.n_qlayers || self.a_bits.len() != meta.n_qlayers {
            bail!("bit config length {} != {} layers", self.w_bits.len(), meta.n_qlayers);
        }
        for q in &meta.qlayers {
            let (w, a) = (self.w_bits[q.index], self.a_bits[q.index]);
            if q.pinned {
                if w != meta.pin_bits || a != meta.pin_bits {
                    bail!("layer {} is pinned to {} bits, got W{w}A{a}", q.name, meta.pin_bits);
                }
            } else if !meta.bit_options.contains(&w) || !meta.bit_options.contains(&a) {
                bail!("layer {}: W{w}A{a} outside options {:?}", q.name, meta.bit_options);
            }
        }
        Ok(())
    }

    /// Average weight bit-width over non-pinned layers (weighted by size).
    pub fn avg_w_bits(&self, meta: &ModelMeta) -> f64 {
        let mut num = 0.0;
        let mut den = 0.0;
        for q in &meta.qlayers {
            num += self.w_bits[q.index] as f64 * q.w_numel as f64;
            den += q.w_numel as f64;
        }
        num / den
    }
}

/// Reference host-side fake-quantizer (for tests / sanity checks only;
/// the real path runs inside the AOT artifacts).
pub fn fake_quant_host(v: &[f32], s: f32, qmin: f32, qmax: f32) -> Vec<f32> {
    let s = s.max(1e-9);
    v.iter().map(|&x| (x / s).clamp(qmin, qmax).round_ties_even() * s).collect()
}

/// Quantize a float buffer to integer codes into a reusable output buffer
/// (the activation path of the integer deployment simulator; allocation-
/// free once `out` has warmed up).
pub fn quantize_codes_into(v: &[f32], s: f32, qmin: f32, qmax: f32, out: &mut Vec<i64>) {
    out.clear();
    out.extend(v.iter().map(|&x| (x / s).clamp(qmin, qmax).round_ties_even() as i64));
}

/// LSQ statistics-based scale init (paper §3.3.2 / LSQ+):
/// s0 = 2·E|w| / sqrt(qmax).
pub fn scale_init_stats(values: &[f32], qmax: f32) -> f32 {
    (2.0 * mean_abs(values) as f32 / qmax.sqrt()).max(1e-6)
}

/// Uniform-value init scheme from the paper's Fig. 2 ablation:
/// s_b = 0.1 / b.
pub fn scale_init_uniform(bits: u8) -> f32 {
    0.1 / bits as f32
}

/// Activation scale init when no calibration data is available:
/// assume post-ReLU activations with E|a| ≈ 0.5.
pub fn act_scale_init(qmax: f32) -> f32 {
    (2.0 * 0.5 / qmax.sqrt()).max(1e-6)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_match_paper_eq1() {
        assert_eq!(weight_bounds(2), (-2.0, 1.0));
        assert_eq!(weight_bounds(4), (-8.0, 7.0));
        assert_eq!(weight_bounds(8), (-128.0, 127.0));
        assert_eq!(act_bounds(2), (0.0, 3.0));
        assert_eq!(act_bounds(4), (0.0, 15.0));
        assert_eq!(act_bounds(8), (0.0, 255.0));
    }

    #[test]
    fn fake_quant_host_matches_semantics() {
        let v = [0.26, -0.26, 10.0, -10.0];
        let q = fake_quant_host(&v, 0.1, -8.0, 7.0);
        // 0.26/0.1=2.6 -> 3 -> 0.3 ; 10/0.1=100 -> clip 7 -> 0.7
        assert!((q[0] - 0.3).abs() < 1e-6);
        assert!((q[1] + 0.3).abs() < 1e-6);
        assert!((q[2] - 0.7).abs() < 1e-6);
        assert!((q[3] + 0.8).abs() < 1e-6);
    }

    #[test]
    fn scale_inits() {
        let w = [0.1f32, -0.1, 0.2, -0.2];
        let s = scale_init_stats(&w, 7.0);
        assert!((s - 2.0 * 0.15 / 7f32.sqrt()).abs() < 1e-6);
        assert!((scale_init_uniform(2) - 0.05).abs() < 1e-9);
        assert!(scale_init_uniform(2) > scale_init_uniform(6)); // grows as bits shrink
        assert!(act_scale_init(3.0) > act_scale_init(255.0));
    }

    #[test]
    fn quantize_codes_reuses_buffer() {
        let mut out = Vec::new();
        quantize_codes_into(&[0.26, -0.26, 10.0], 0.1, -8.0, 7.0, &mut out);
        assert_eq!(out, vec![3, -3, 7]);
        let cap = out.capacity();
        quantize_codes_into(&[0.0, 0.1], 0.1, -8.0, 7.0, &mut out);
        assert_eq!(out, vec![0, 1]);
        assert_eq!(out.capacity(), cap, "no reallocation on reuse");
    }

    #[test]
    fn qmax_off_is_fp_like() {
        let v = [0.123456f32, -3.14159];
        let q = fake_quant_host(&v, 1e-4, -QMAX_OFF - 1.0, QMAX_OFF);
        for (a, b) in q.iter().zip(v.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn bitconfig_qmax_vectors() {
        let c = BitConfig { w_bits: vec![2, 8], a_bits: vec![3, 4] };
        let (qw, qa) = c.qmax_vectors();
        assert_eq!(qw, vec![1.0, 127.0]);
        assert_eq!(qa, vec![7.0, 15.0]);
    }
}
