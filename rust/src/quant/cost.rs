//! Cost models: BitOps and model size (the paper's two constraint types).
//!
//! BitOps(l, b_w, b_a) = MACs_l · b_w · b_a           (paper eq. 2b / 3b)
//! size(l, b_w)        = w_numel_l · b_w / 8 bytes    (Table 3/5 "Size")

use crate::models::ModelMeta;
use crate::quant::BitConfig;

/// BitOps of one layer at a (w, a) bit pair.
pub fn layer_bitops(macs: u64, w_bits: u8, a_bits: u8) -> u64 {
    macs * w_bits as u64 * a_bits as u64
}

/// Total BitOps of a policy (per example).
pub fn total_bitops(meta: &ModelMeta, cfg: &BitConfig) -> u64 {
    meta.qlayers
        .iter()
        .map(|q| layer_bitops(q.macs, cfg.w_bits[q.index], cfg.a_bits[q.index]))
        .sum()
}

/// Total BitOps in G (the unit the paper's tables report).
pub fn total_bitops_g(meta: &ModelMeta, cfg: &BitConfig) -> f64 {
    total_bitops(meta, cfg) as f64 / 1e9
}

/// Quantized weight bytes of one layer.
pub fn layer_size_bits(w_numel: u64, w_bits: u8) -> u64 {
    w_numel * w_bits as u64
}

/// Quantized model size in bytes.
pub fn model_size_bytes(meta: &ModelMeta, cfg: &BitConfig) -> u64 {
    let bits: u64 = meta
        .qlayers
        .iter()
        .map(|q| layer_size_bits(q.w_numel, cfg.w_bits[q.index]))
        .sum();
    bits.div_ceil(8)
}

/// FP32 model size in bytes (weights of quantized layers only — matches
/// how the paper computes compression rate).
pub fn fp_size_bytes(meta: &ModelMeta) -> u64 {
    meta.total_weights() * 4
}

/// Weight compression rate ("W-C" column of Table 3).
pub fn compression_rate(meta: &ModelMeta, cfg: &BitConfig) -> f64 {
    fp_size_bytes(meta) as f64 / model_size_bytes(meta, cfg) as f64
}

/// BitOps of the uniform (fixed-precision) baseline at w/a bits, with
/// first/last pinned — the reference constraint levels in Tables 2/4
/// ("3-bit level", "4-bit level").
pub fn uniform_bitops(meta: &ModelMeta, w: u8, a: u8) -> u64 {
    total_bitops(meta, &BitConfig::uniform_pinned(meta, w, a))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelMeta;
    use crate::util::json::Json;
    use std::path::Path;

    fn meta2() -> ModelMeta {
        let j = Json::parse(
            r#"{
          "name": "t", "param_size": 10, "n_qlayers": 2,
          "input_shape": [2,2,1], "n_classes": 2,
          "train_batch": 4, "eval_batch": 8, "serve_batch": 2,
          "bit_options": [2,3,4,5,6], "pin_bits": 8,
          "params": [
            {"name":"l0.w","shape":[10],"offset":0,"size":10,"init":"zeros","fan_in":2}
          ],
          "qlayers": [
            {"index":0,"name":"l0","kind":"dense","macs":1000,"w_numel":100,"pinned":false},
            {"index":1,"name":"l1","kind":"conv","macs":500,"w_numel":50,"pinned":false}
          ],
          "artifacts": {}
        }"#,
        )
        .unwrap();
        ModelMeta::from_json(&j, Path::new("/tmp")).unwrap()
    }

    #[test]
    fn bitops_formula() {
        assert_eq!(layer_bitops(1000, 4, 4), 16000);
        let m = meta2();
        let c = BitConfig { w_bits: vec![4, 2], a_bits: vec![4, 3] };
        assert_eq!(total_bitops(&m, &c), 1000 * 16 + 500 * 6);
    }

    #[test]
    fn size_and_compression() {
        let m = meta2();
        let c = BitConfig::uniform(2, 4, 4);
        assert_eq!(model_size_bytes(&m, &c), (150 * 4_u64).div_ceil(8));
        assert_eq!(fp_size_bytes(&m), 600);
        assert!((compression_rate(&m, &c) - 8.0).abs() < 0.1);
    }

    #[test]
    fn more_bits_cost_more() {
        let m = meta2();
        for b in 2..6u8 {
            assert!(uniform_bitops(&m, b, b) < uniform_bitops(&m, b + 1, b + 1));
        }
    }
}
