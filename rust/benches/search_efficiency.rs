//! §4.3 bench: MPQ policy search time on the *real* model metas
//! (importances from stats init if no trained cache exists — solve time is
//! importance-value independent).  Reproduces the "ILP solves in
//! milliseconds, independent of training data" headline.
//!
//! Run: make artifacts && cargo bench --bench search_efficiency

use std::path::Path;

use limpq::coordinator::checkpoint::Cache;
use limpq::importance::IndicatorStore;
use limpq::models::{list_models, ModelMeta};
use limpq::quant::cost::uniform_bitops;
use limpq::search::{solve, MpqProblem};
use limpq::util::bench::Bench;
use limpq::util::rng::Rng;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let bench = Bench::default();
    let cache = Cache::new(Path::new("runs")).ok();

    for model in list_models(dir).unwrap() {
        let meta = ModelMeta::load(dir, &model).unwrap();
        // Trained indicators when available, stats-init otherwise.
        let store = cache
            .as_ref()
            .and_then(|c| c.load_indicators(&model).ok().flatten())
            .unwrap_or_else(|| {
                let mut rng = Rng::new(1);
                let flat = meta.init_params(&mut rng);
                IndicatorStore::init_stats(&meta, &flat)
            });
        let imp = store.importance(&meta);
        let alpha = limpq::config::Config::paper_alpha(&model);

        for (label, bits) in [("3bit", 3u8), ("4bit", 4u8)] {
            let cap = uniform_bitops(&meta, bits, bits);
            let p = MpqProblem::from_importance(&meta, &imp, alpha, Some(cap), None, false);
            let stats = bench.run(&format!("ilp_{model}_{label}(L={},vars={})", meta.n_qlayers, p.n_vars()), || {
                solve(&p).unwrap()
            });
            // The paper's ResNet18 number: 0.06 s. Flag regressions hard.
            if stats.mean.as_secs_f64() > 1.0 {
                println!("WARNING: {model} {label} ILP slower than 1 s");
            }
        }

        // Weight-only (Table 5 shape) and two-constraint (Table 3 shape).
        let cap = uniform_bitops(&meta, 3, 3);
        let pw = MpqProblem::from_importance(&meta, &imp, alpha, None, Some(meta.total_weights() * 3), true);
        bench.run(&format!("ilp_{model}_weight_only"), || solve(&pw).unwrap());
        let p2 = MpqProblem::from_importance(&meta, &imp, alpha, Some(cap), Some(meta.total_weights() * 3), false);
        bench.run(&format!("ilp_{model}_two_constraint"), || solve(&p2).unwrap());
    }
}
