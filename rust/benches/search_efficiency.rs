//! §4.3 bench: MPQ policy search time on the *real* model metas
//! (importances from stats init if no trained cache exists — solve time is
//! importance-value independent).  Reproduces the "ILP solves in
//! milliseconds, independent of training data" headline, now through the
//! PolicyEngine front-end: cold solves per constraint shape plus the
//! memoized repeat-query path a fleet server actually serves.
//!
//! Run: make artifacts && cargo bench --bench search_efficiency

use std::path::Path;

use limpq::coordinator::checkpoint::Cache;
use limpq::engine::{PolicyEngine, SearchRequest};
use limpq::importance::IndicatorStore;
use limpq::models::{list_models, ModelMeta};
use limpq::quant::cost::uniform_bitops;
use limpq::util::bench::Bench;
use limpq::util::rng::Rng;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let bench = Bench::default();
    let cache = Cache::new(Path::new("runs")).ok();

    for model in list_models(dir).unwrap() {
        let meta = ModelMeta::load(dir, &model).unwrap();
        // Trained indicators when available, stats-init otherwise.
        let store = cache
            .as_ref()
            .and_then(|c| c.load_indicators(&model).ok().flatten())
            .unwrap_or_else(|| {
                let mut rng = Rng::new(1);
                let flat = meta.init_params(&mut rng);
                IndicatorStore::init_stats(&meta, &flat)
            });
        let imp = store.importance(&meta);
        let alpha = limpq::config::Config::paper_alpha(&model);
        let engine = PolicyEngine::new(meta.clone(), imp);

        for (label, bits) in [("3bit", 3u8), ("4bit", 4u8)] {
            let cap = uniform_bitops(&meta, bits, bits);
            let req = SearchRequest::builder().alpha(alpha).bitops_cap(cap).build().unwrap();
            let n_vars = engine.problem(&req).n_vars();
            let stats = bench.run(
                &format!("ilp_{model}_{label}(L={},vars={n_vars})", meta.n_qlayers),
                || engine.solve_uncached(&req).unwrap(),
            );
            // The paper's ResNet18 number: 0.06 s. Flag regressions hard.
            if stats.mean.as_secs_f64() > 1.0 {
                println!("WARNING: {model} {label} ILP slower than 1 s");
            }
        }

        // Weight-only (Table 5 shape) and two-constraint (Table 3 shape).
        let cap = uniform_bitops(&meta, 3, 3);
        let req_w = SearchRequest::builder()
            .alpha(alpha)
            .size_cap_bits(meta.total_weights() * 3)
            .weight_only(true)
            .build()
            .unwrap();
        bench.run(&format!("ilp_{model}_weight_only"), || engine.solve_uncached(&req_w).unwrap());
        let req_2 = SearchRequest::builder()
            .alpha(alpha)
            .bitops_cap(cap)
            .size_cap_bits(meta.total_weights() * 3)
            .build()
            .unwrap();
        bench.run(&format!("ilp_{model}_two_constraint"), || {
            engine.solve_uncached(&req_2).unwrap()
        });

        // The fleet serving path: identical repeated query, memoized.
        let req = SearchRequest::builder()
            .alpha(alpha)
            .bitops_cap(uniform_bitops(&meta, 4, 4))
            .build()
            .unwrap();
        engine.solve(&req).unwrap(); // warm
        bench.run(&format!("ilp_{model}_cached_repeat"), || engine.solve(&req).unwrap());

        // The stampede path: 8 threads fire the *same cold* query (a
        // fresh constraint every iteration); single-flight collapses each
        // volley onto one solve, so this costs ~1x a cold solve plus
        // wake-up overhead, not 8x.
        let stamp_base = uniform_bitops(&meta, 5, 5);
        let iter = std::sync::atomic::AtomicU64::new(0);
        bench.run(&format!("ilp_{model}_stampede8"), || {
            let cap = stamp_base + iter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let req =
                SearchRequest::builder().alpha(alpha).bitops_cap(cap).build().unwrap();
            std::thread::scope(|s| {
                for _ in 0..8 {
                    s.spawn(|| engine.solve(&req).unwrap());
                }
            });
        });
        let c = engine.cache_stats();
        println!(
            "cache[{model}]: {} hits / {} solves ({:.1}% hit rate), {} single-flight waits",
            c.hits,
            c.hits + c.misses,
            100.0 * c.hit_rate(),
            c.inflight_waits
        );
    }
}
