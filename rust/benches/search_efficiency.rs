//! §4.3 bench: MPQ policy search time on the *real* model metas
//! (importances from stats init if no trained cache exists — solve time is
//! importance-value independent).  Reproduces the "ILP solves in
//! milliseconds, independent of training data" headline, now through the
//! PolicyEngine front-end: cold solves per constraint shape plus the
//! memoized repeat-query path a fleet server actually serves.
//!
//! Run: make artifacts && cargo bench --bench search_efficiency

use std::path::Path;

use limpq::coordinator::checkpoint::Cache;
use limpq::engine::{CancelToken, PolicyEngine, SearchRequest};
use limpq::importance::IndicatorStore;
use limpq::kernels::pool::WorkerPool;
use limpq::models::{list_models, ModelMeta};
use limpq::quant::cost::uniform_bitops;
use limpq::search::lagrange::solve_lagrange;
use limpq::search::{prune_dominated, Granularity, MpqProblem};
use limpq::util::bench::{json_out_arg, json_record, Bench};
use limpq::util::json::Json;
use limpq::util::rng::Rng;

/// ResNet18-shaped meta with real output-channel counts (stem, four
/// stages of BasicBlocks, classifier; first/last pinned).  Channel
/// granularity turns it into a fine-grained MCKP instance: channel:8
/// splits the 3840 unpinned channels into 480 groups of 36 (w, a)
/// options each; kernel granularity goes all the way to 3840 groups.
fn resnet18_like_meta() -> ModelMeta {
    let chans: [usize; 18] =
        [64, 64, 64, 64, 64, 128, 128, 128, 128, 256, 256, 256, 256, 512, 512, 512, 512, 10];
    let mut params = String::new();
    let mut qlayers = String::new();
    let mut off = 0usize;
    for (i, &c) in chans.iter().enumerate() {
        let size = c * 16;
        if i > 0 {
            params.push(',');
            qlayers.push(',');
        }
        params.push_str(&format!(
            r#"{{"name":"l{i}.w","shape":[{c},16],"offset":{off},"size":{size},"init":"he_dense","fan_in":16}}"#
        ));
        qlayers.push_str(&format!(
            r#"{{"index":{i},"name":"l{i}","kind":"conv","macs":{},"w_numel":{size},"pinned":{}}}"#,
            size as u64 * 49,
            i == 0 || i + 1 == chans.len()
        ));
        off += size;
    }
    let text = format!(
        r#"{{"name":"resnet18_like","param_size":{off},"n_qlayers":{},
          "input_shape":[8,8,3],"n_classes":10,
          "train_batch":4,"eval_batch":8,"serve_batch":2,
          "bit_options":[2,3,4,5,6,8],"pin_bits":8,
          "params":[{params}],"qlayers":[{qlayers}],"artifacts":{{}}}}"#,
        chans.len()
    );
    ModelMeta::from_json(&Json::parse(&text).unwrap(), Path::new("/tmp")).unwrap()
}

/// The fine-granularity tiers: the decomposed Lagrangian solver core on
/// the same ResNet18-scale instance at layer / channel:8 / kernel
/// granularity.  Each tier records wall time at 1 thread with dominance
/// pruning off (the disabled baseline) and at N threads on the pruned
/// instance, plus the prune ratio and the rounded-vs-bound gap — the
/// numbers the CI regression diff watches.
fn fine_granularity_tiers(bench: &Bench) -> Vec<Json> {
    let meta = resnet18_like_meta();
    let imp = IndicatorStore::init_uniform(&meta).importance(&meta);
    // A small alpha weights activation importance; on the (w, a) grid
    // that leaves many dominated combinations for the pruner to drop.
    let alpha = 0.1;
    let cap = uniform_bitops(&meta, 4, 4);
    let pool = WorkerPool::global();
    let threads = pool.threads();
    let base_pool = WorkerPool::new(1);
    let mut records = Vec::new();
    for (tier, g) in [
        ("search_fine_layer", Granularity::Layer),
        ("search_fine_channel", Granularity::ChannelGroup(8)),
        ("search_fine_kernel", Granularity::Kernel),
    ] {
        let p = MpqProblem::from_importance(&meta, &imp, alpha, Some(cap), None, false, g);
        let n_vars = p.n_vars();
        let pruned = prune_dominated(&p);
        let prune_ratio = pruned.dropped as f64 / n_vars.max(1) as f64;
        let size = format!("vars={n_vars}");
        let base = bench.run(&format!("{tier}_base(vars={n_vars},t=1)"), || {
            solve_lagrange(&p, &base_pool, None, &CancelToken::none()).unwrap()
        });
        let (sol, st) = solve_lagrange(&pruned.problem, &pool, None, &CancelToken::none())
            .expect("fine solve");
        let gap = (sol.cost - st.bound).max(0.0) / sol.cost.abs().max(1e-12);
        let fast = bench.run(&format!("{tier}(vars={n_vars},t={threads})"), || {
            solve_lagrange(&pruned.problem, &pool, None, &CancelToken::none()).unwrap()
        });
        let speedup = base.mean.as_secs_f64() / fast.mean.as_secs_f64().max(1e-12);
        println!(
            "{tier}: {n_vars} vars, {:.0}% pruned, bound gap {:.3}%, \
             {speedup:.1}x vs pruning+parallelism disabled",
            100.0 * prune_ratio,
            100.0 * gap,
        );
        if tier == "search_fine_channel" && speedup < 5.0 {
            println!("WARNING: {tier} speedup {speedup:.1}x below the 5x target");
        }
        for (stats, t) in [(&base, 1usize), (&fast, threads)] {
            let mut rec = json_record(tier, &size, t, stats, 1.0);
            if let Json::Obj(m) = &mut rec {
                m.insert("vars".into(), Json::Num(n_vars as f64));
                m.insert("prune_ratio".into(), Json::Num(prune_ratio));
                m.insert("bound_gap".into(), Json::Num(gap));
                m.insert("speedup".into(), Json::Num(speedup));
            }
            records.push(rec);
        }
    }
    records
}

fn main() {
    let json_path = json_out_arg();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let bench = if quick { Bench::quick() } else { Bench::default() };

    // The synthetic fine-granularity tiers run (and emit BENCH_search
    // records) even without built artifacts, so CI smoke always gets an
    // artifact to diff.
    let records = fine_granularity_tiers(&bench);
    if let Some(path) = &json_path {
        std::fs::write(path, Json::Arr(records).to_string()).expect("write bench json");
        println!("search bench records -> {path}");
    }

    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let cache = Cache::new(Path::new("runs")).ok();

    for model in list_models(dir).unwrap() {
        let meta = ModelMeta::load(dir, &model).unwrap();
        // Trained indicators when available, stats-init otherwise.
        let store = cache
            .as_ref()
            .and_then(|c| c.load_indicators(&model).ok().flatten())
            .unwrap_or_else(|| {
                let mut rng = Rng::new(1);
                let flat = meta.init_params(&mut rng);
                IndicatorStore::init_stats(&meta, &flat)
            });
        let imp = store.importance(&meta);
        let alpha = limpq::config::Config::paper_alpha(&model);
        let engine = PolicyEngine::new(meta.clone(), imp);

        for (label, bits) in [("3bit", 3u8), ("4bit", 4u8)] {
            let cap = uniform_bitops(&meta, bits, bits);
            let req = SearchRequest::builder().alpha(alpha).bitops_cap(cap).build().unwrap();
            let n_vars = engine.problem(&req).n_vars();
            let stats = bench.run(
                &format!("ilp_{model}_{label}(L={},vars={n_vars})", meta.n_qlayers),
                || engine.solve_uncached(&req).unwrap(),
            );
            // The paper's ResNet18 number: 0.06 s. Flag regressions hard.
            if stats.mean.as_secs_f64() > 1.0 {
                println!("WARNING: {model} {label} ILP slower than 1 s");
            }
        }

        // Weight-only (Table 5 shape) and two-constraint (Table 3 shape).
        let cap = uniform_bitops(&meta, 3, 3);
        let req_w = SearchRequest::builder()
            .alpha(alpha)
            .size_cap_bits(meta.total_weights() * 3)
            .weight_only(true)
            .build()
            .unwrap();
        bench.run(&format!("ilp_{model}_weight_only"), || engine.solve_uncached(&req_w).unwrap());
        let req_2 = SearchRequest::builder()
            .alpha(alpha)
            .bitops_cap(cap)
            .size_cap_bits(meta.total_weights() * 3)
            .build()
            .unwrap();
        bench.run(&format!("ilp_{model}_two_constraint"), || {
            engine.solve_uncached(&req_2).unwrap()
        });

        // The fleet serving path: identical repeated query, memoized.
        let req = SearchRequest::builder()
            .alpha(alpha)
            .bitops_cap(uniform_bitops(&meta, 4, 4))
            .build()
            .unwrap();
        engine.solve(&req).unwrap(); // warm
        bench.run(&format!("ilp_{model}_cached_repeat"), || engine.solve(&req).unwrap());

        // The stampede path: 8 threads fire the *same cold* query (a
        // fresh constraint every iteration); single-flight collapses each
        // volley onto one solve, so this costs ~1x a cold solve plus
        // wake-up overhead, not 8x.
        let stamp_base = uniform_bitops(&meta, 5, 5);
        let iter = std::sync::atomic::AtomicU64::new(0);
        bench.run(&format!("ilp_{model}_stampede8"), || {
            let cap = stamp_base + iter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let req =
                SearchRequest::builder().alpha(alpha).bitops_cap(cap).build().unwrap();
            std::thread::scope(|s| {
                for _ in 0..8 {
                    s.spawn(|| engine.solve(&req).unwrap());
                }
            });
        });
        let c = engine.cache_stats();
        println!(
            "cache[{model}]: {} hits / {} solves ({:.1}% hit rate), {} single-flight waits",
            c.hits,
            c.hits + c.misses,
            100.0 * c.hit_rate(),
            c.inflight_waits
        );
    }
}
