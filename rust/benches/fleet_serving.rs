//! Fleet-serving throughput bench: queries/sec through the event-driven
//! TCP stack (multiplexer → coalescing dispatcher → single-flight
//! engine) at 1, 8, and 64 concurrent clients, cold vs warm policy
//! cache.  A second tier measures the multi-model registry: round-robin
//! queries over 2 and 8 resident models (`fleet_multi_hit`) and the same
//! round-robin under a memory budget that only fits half the set, so
//! every access is an LRU evict + reload (`fleet_multi_reload`).
//! Artifact-free: runs on a synthetic model meta, so the serving
//! machinery — not the solver — dominates what is measured (requests pin
//! the fast `greedy` solver).  A `fleet_frontier` tier sends
//! distinct-cap auto-solver queries with the certified Pareto surface
//! on (every answer a frontier hit, no solver) vs off (every answer a
//! cold exact solve) — the hot-path speedup the frontier subsystem buys.
//! Where epoll is available, a `fleet_epoll` / `fleet_sweep` tier runs
//! the same warm volleys through both readiness backends.
//!
//! Run: cargo bench --bench fleet_serving [-- --json BENCH_fleet.json]
//!
//! `--json PATH` writes machine-readable records (op, size, threads,
//! ns_per_iter, throughput = queries/sec) — `tools/bench.sh` uploads the
//! file alongside BENCH_kernels.json to track the serving trajectory.
//! Set `BENCH_QUICK=1` for the CI smoke run.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use limpq::engine::{BranchAndBound, PolicyEngine};
use limpq::fleet::faults::{FaultPlan, FaultySolver};
use limpq::fleet::{FleetSearcher, FleetServer, PollBackend, ServeConfig};
use limpq::importance::IndicatorStore;
use limpq::kernels::WorkerPool;
use limpq::models::synthetic_meta;
use limpq::quant::cost::uniform_bitops;
use limpq::registry::{ModelEntry, ModelRegistry, RegistryConfig, StaticSource};
use limpq::util::bench::{json_out_arg, json_record, Bench, BenchStats};
use limpq::util::json::Json;

/// One machine-readable record for BENCH_fleet.json (shared schema from
/// `util::bench`; fleet records count queries as the items).
fn record(op: &str, size: &str, threads: usize, stats: &BenchStats, queries: f64) -> Json {
    json_record(op, size, threads, stats, queries)
}

/// One volley: `clients` concurrent connections, each sending
/// `per_client` line-protocol requests and reading every response.
/// Warm volleys repeat one cached constraint; cold volleys draw fresh
/// constraints from `counter` so every query misses the policy cache.
fn volley(
    addr: std::net::SocketAddr,
    clients: usize,
    per_client: usize,
    warm: bool,
    base: u64,
    counter: &AtomicU64,
) {
    std::thread::scope(|scope| {
        for _ in 0..clients {
            scope.spawn(|| {
                let stream = TcpStream::connect(addr).expect("connect");
                stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
                let mut writer = stream.try_clone().unwrap();
                let mut reader = BufReader::new(stream);
                for _ in 0..per_client {
                    let cap = if warm {
                        base
                    } else {
                        base + 1000 * (1 + counter.fetch_add(1, Ordering::Relaxed))
                    };
                    let line = format!(
                        "{{\"cap_gbitops\": {}, \"solver\": \"greedy\"}}\n",
                        cap as f64 / 1e9
                    );
                    writer.write_all(line.as_bytes()).unwrap();
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    let ok = Json::parse(resp.trim())
                        .expect("parse response")
                        .get("ok")
                        .unwrap()
                        .as_bool()
                        .unwrap();
                    assert!(ok, "serve error: {resp}");
                }
            });
        }
    });
}

/// Fault-tier volley: like [`volley`] cold mode but every request
/// carries a tight `deadline_ms`, and degraded answers are counted
/// instead of rejected (they are still `"ok": true` lines — the
/// exactly-one-response discipline is what the tier measures under
/// injected stalls).
fn fault_volley(
    addr: std::net::SocketAddr,
    clients: usize,
    per_client: usize,
    base: u64,
    counter: &AtomicU64,
    degraded: &AtomicU64,
) {
    std::thread::scope(|scope| {
        for _ in 0..clients {
            scope.spawn(|| {
                let stream = TcpStream::connect(addr).expect("connect");
                stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
                let mut writer = stream.try_clone().unwrap();
                let mut reader = BufReader::new(stream);
                for _ in 0..per_client {
                    let cap = base + 1000 * (1 + counter.fetch_add(1, Ordering::Relaxed));
                    let line = format!(
                        "{{\"cap_gbitops\": {}, \"deadline_ms\": 25}}\n",
                        cap as f64 / 1e9
                    );
                    writer.write_all(line.as_bytes()).unwrap();
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    let resp = Json::parse(resp.trim()).expect("parse response");
                    assert!(resp.get("ok").unwrap().as_bool().unwrap(), "serve error: {resp}");
                    if resp.opt("degraded").is_some() {
                        degraded.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
}

/// Frontier-tier volley: distinct caps like [`volley`] cold mode, but
/// auto solver (no pin) so each query is eligible for the frontier hot
/// path whenever the server has it enabled.
fn frontier_volley(
    addr: std::net::SocketAddr,
    clients: usize,
    per_client: usize,
    base: u64,
    counter: &AtomicU64,
) {
    std::thread::scope(|scope| {
        for _ in 0..clients {
            scope.spawn(|| {
                let stream = TcpStream::connect(addr).expect("connect");
                stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
                let mut writer = stream.try_clone().unwrap();
                let mut reader = BufReader::new(stream);
                for _ in 0..per_client {
                    let cap = base + 1000 * (1 + counter.fetch_add(1, Ordering::Relaxed));
                    let line = format!("{{\"cap_gbitops\": {}}}\n", cap as f64 / 1e9);
                    writer.write_all(line.as_bytes()).unwrap();
                    let mut resp = String::new();
                    reader.read_line(&mut resp).unwrap();
                    let ok = Json::parse(resp.trim())
                        .expect("parse response")
                        .get("ok")
                        .unwrap()
                        .as_bool()
                        .unwrap();
                    assert!(ok, "serve error: {resp}");
                }
            });
        }
    });
}

/// `nmodels` identically-shaped synthetic models m0..m{n-1}; entries
/// rebuild from assets on every load, so an evict/reload cycle costs
/// what a real reload would (importance + engine construction).
fn multi_source(nmodels: usize) -> StaticSource {
    let mut src = StaticSource::new();
    for m in 0..nmodels {
        let meta = synthetic_meta(8, |i| 50_000 * (i as u64 + 1));
        let store = IndicatorStore::init_uniform(&meta);
        src = src.with_assets(&format!("m{m}"), meta, store, None);
    }
    src
}

/// One client, `queries` sequential solves round-robining the models —
/// sequential on purpose: with cached policies the solve is O(1), so the
/// registry lookup (hit) or evict+reload (thrash) dominates.
fn multi_volley(addr: std::net::SocketAddr, nmodels: usize, queries: usize, base: u64) {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    for q in 0..queries {
        let line = format!(
            "{{\"model\": \"m{}\", \"cap_gbitops\": {}, \"solver\": \"greedy\"}}\n",
            q % nmodels,
            base as f64 / 1e9
        );
        writer.write_all(line.as_bytes()).unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        let ok = Json::parse(resp.trim())
            .expect("parse response")
            .get("ok")
            .unwrap()
            .as_bool()
            .unwrap();
        assert!(ok, "serve error: {resp}");
    }
}

fn main() {
    let json_path = json_out_arg();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let per_client = if quick { 2 } else { 8 };

    let meta = synthetic_meta(8, |i| 50_000 * (i as u64 + 1));
    let imp = IndicatorStore::init_uniform(&meta).importance(&meta);
    let base = uniform_bitops(&meta, 4, 4);
    let searcher = FleetSearcher::new(meta, imp);
    let stats_view = searcher.clone();
    let server = FleetServer::spawn_with(
        searcher,
        "127.0.0.1:0",
        ServeConfig { max_conns: 256, ..Default::default() },
    )
    .expect("spawn fleet server");
    let addr = server.addr;
    let threads = WorkerPool::global().threads();

    let counter = AtomicU64::new(0);
    let mut records: Vec<Json> = Vec::new();
    for &clients in &[1usize, 8, 64] {
        for (mode, warm) in [("cold", false), ("warm", true)] {
            let queries = (clients * per_client) as f64;
            let stats = bench.run(&format!("fleet_serve_{mode}_c{clients}x{per_client}"), || {
                volley(addr, clients, per_client, warm, base, &counter);
            });
            println!(
                "fleet {mode} @ {clients} clients: {:.0} queries/sec",
                queries / stats.mean.as_secs_f64()
            );
            records.push(record(
                &format!("fleet_serve_{mode}"),
                &format!("clients={clients}"),
                threads,
                &stats,
                queries,
            ));
        }
    }

    let sv = server.stats();
    let cs = stats_view.cache_stats();
    println!(
        "serving totals: {} responses, {} batches (max coalesced {}), \
         {} cache hits / {} solves, {} single-flight waits, {} conns total",
        sv.served,
        sv.batches,
        sv.coalesced_batch_max,
        cs.hits,
        cs.hits + cs.misses,
        cs.inflight_waits,
        sv.conns_total
    );
    server.shutdown();

    // Multi-model registry tier: hit (everything resident) vs reload
    // (budget fits half the set, so round-robin access thrashes the LRU
    // and every query pays an evict + rebuild).
    let probe = ModelRegistry::new(Box::new(multi_source(1)), RegistryConfig::default());
    let model_bytes = probe.get("m0").expect("probe model").bytes();
    for &nmodels in &[2usize, 8] {
        let queries = nmodels * if quick { 2 } else { 8 };
        for mode in ["hit", "reload"] {
            let rcfg = match mode {
                "hit" => RegistryConfig::default(),
                _ => RegistryConfig {
                    mem_budget: Some(model_bytes * (nmodels / 2) + 64),
                    ..RegistryConfig::default()
                },
            };
            let registry = Arc::new(ModelRegistry::new(Box::new(multi_source(nmodels)), rcfg));
            let server =
                FleetServer::spawn_registry(registry, "m0", "127.0.0.1:0", ServeConfig::default())
                    .expect("spawn multi-model server");
            let addr = server.addr;
            // Unmeasured settle pass: in hit mode it loads every model
            // and primes each policy cache; in reload mode it reaches
            // the steady thrash state.
            multi_volley(addr, nmodels, queries, base);
            let stats = bench.run(&format!("fleet_multi_{mode}_m{nmodels}x{queries}"), || {
                multi_volley(addr, nmodels, queries, base);
            });
            let rs = server.registry().stats();
            println!(
                "fleet multi {mode} @ {nmodels} models: {:.0} queries/sec \
                 ({} resident, {} loads, {} evictions)",
                queries as f64 / stats.mean.as_secs_f64(),
                rs.models.len(),
                rs.loads,
                rs.evictions
            );
            records.push(record(
                &format!("fleet_multi_{mode}"),
                &format!("models={nmodels}"),
                threads,
                &stats,
                queries as f64,
            ));
            server.shutdown();
        }
    }

    // Frontier tier: every query draws a fresh cap, so nothing ever hits
    // the policy cache — with the surface on, every answer is a frontier
    // hit (no solver runs after the settle pass builds the surface);
    // with it off, every answer is a cold exact solve.  The ratio is the
    // hot-path speedup the precomputed surface buys.
    for (mode, frontier) in [("hit", true), ("off", false)] {
        let meta = synthetic_meta(8, |i| 50_000 * (i as u64 + 1));
        let imp = IndicatorStore::init_uniform(&meta).importance(&meta);
        let server = FleetServer::spawn_with(
            FleetSearcher::new(meta, imp),
            "127.0.0.1:0",
            ServeConfig { frontier, frontier_tol: 10.0, ..Default::default() },
        )
        .expect("spawn frontier server");
        let addr = server.addr;
        let clients = 8usize;
        let counter = AtomicU64::new(0);
        let queries = (clients * per_client) as f64;
        // Unmeasured settle pass: builds the surface once (hit mode).
        frontier_volley(addr, clients, per_client, base, &counter);
        let stats = bench.run(&format!("fleet_frontier_{mode}_c{clients}x{per_client}"), || {
            frontier_volley(addr, clients, per_client, base, &counter);
        });
        let sv = server.stats();
        println!(
            "fleet frontier {mode} @ {clients} clients: {:.0} queries/sec \
             ({} frontier hits / {} misses / {} refines)",
            queries / stats.mean.as_secs_f64(),
            sv.frontier_hits,
            sv.frontier_misses,
            sv.frontier_refines
        );
        records.push(record(
            &format!("fleet_frontier_{mode}"),
            &format!("clients={clients}"),
            threads,
            &stats,
            queries,
        ));
        server.shutdown();
    }

    // Fault tier: every 10th solve stalls well past a tight per-request
    // deadline, so ~10% of answers come back degraded.  Measures serving
    // throughput with deadline supervision and the degradation chain
    // active — the robustness machinery's overhead on the happy 90%.
    {
        let meta = synthetic_meta(8, |i| 50_000 * (i as u64 + 1));
        let imp = IndicatorStore::init_uniform(&meta).importance(&meta);
        let (solvers, _) = FaultySolver::registry(
            Arc::new(BranchAndBound),
            FaultPlan {
                slow_every: 10,
                slow_delay: Duration::from_millis(30),
                ..FaultPlan::default()
            },
        );
        let engine = Arc::new(PolicyEngine::with_registry(meta, imp, 4096, solvers));
        let registry = Arc::new(ModelRegistry::new(
            Box::new(StaticSource::new().with_entry(ModelEntry::from_engine("m", engine))),
            RegistryConfig::default(),
        ));
        let server =
            FleetServer::spawn_registry(registry, "m", "127.0.0.1:0", ServeConfig::default())
                .expect("spawn faulty server");
        let addr = server.addr;
        let clients = 8usize;
        let counter = AtomicU64::new(0);
        let degraded = AtomicU64::new(0);
        let queries = (clients * per_client) as f64;
        let stats = bench.run(&format!("fleet_faults_c{clients}x{per_client}"), || {
            fault_volley(addr, clients, per_client, base, &counter, &degraded);
        });
        let answered = counter.load(Ordering::Relaxed);
        let shed = degraded.load(Ordering::Relaxed);
        let sv = server.stats();
        println!(
            "fleet faults @ {clients} clients: {:.0} queries/sec, \
             {shed}/{answered} degraded ({} deadline-expired, {} breaker-shed)",
            queries / stats.mean.as_secs_f64(),
            sv.deadline_expired,
            sv.breaker_open
        );
        records.push(record("fleet_faults", &format!("clients={clients}"), threads, &stats, queries));
        server.shutdown();
    }

    // Poll-backend tier: identical warm volleys through the epoll mux
    // and the portable sweep mux, so the readiness backends' serving
    // overhead is directly comparable (the op name carries the backend;
    // the tier only runs where epoll is available).
    if PollBackend::Epoll.available() {
        for (op, poll) in [("fleet_epoll", PollBackend::Epoll), ("fleet_sweep", PollBackend::Sweep)]
        {
            let meta = synthetic_meta(8, |i| 50_000 * (i as u64 + 1));
            let imp = IndicatorStore::init_uniform(&meta).importance(&meta);
            let server = FleetServer::spawn_with(
                FleetSearcher::new(meta, imp),
                "127.0.0.1:0",
                ServeConfig { poll, ..Default::default() },
            )
            .expect("spawn poll-tier server");
            let addr = server.addr;
            let clients = 8usize;
            let counter = AtomicU64::new(0);
            let queries = (clients * per_client) as f64;
            // Unmeasured settle pass primes the policy cache.
            volley(addr, clients, per_client, true, base, &counter);
            let stats = bench.run(&format!("{op}_c{clients}x{per_client}"), || {
                volley(addr, clients, per_client, true, base, &counter);
            });
            let sv = server.stats();
            println!(
                "fleet poll {} @ {clients} clients: {:.0} queries/sec ({} idle wakeups)",
                sv.poll,
                queries / stats.mean.as_secs_f64(),
                sv.idle_wakeups
            );
            records.push(record(op, &format!("clients={clients}"), threads, &stats, queries));
            server.shutdown();
        }
    } else {
        println!("SKIP fleet_epoll tier: epoll not available on this target");
    }

    if let Some(path) = &json_path {
        std::fs::write(path, Json::Arr(records).to_string()).expect("write bench json");
        println!("fleet bench records -> {path}");
    }
}
