//! Paper-table regeneration harness.
//!
//! The accuracy rows of Tables 2-6 cost real training, so this bench
//! consumes the cached `runs/<exp>/result.json` written by the experiment
//! drivers (`limpq exp table2` ...), re-verifies the paper's *shape*
//! claims over them, and re-times the search stage (the cheap,
//! benchmarkable part) live.  If no results are cached it prints how to
//! produce them and exits cleanly — `cargo bench` must never retrain.
//!
//! Run:  cargo run --release -- exp all   # once, populates runs/
//!       cargo bench --bench paper_tables

use std::path::Path;

use limpq::util::json::Json;

struct Claim {
    desc: String,
    ok: bool,
}

fn load(exp: &str) -> Option<Json> {
    let p = Path::new("runs").join(exp).join("result.json");
    let text = std::fs::read_to_string(p).ok()?;
    Json::parse(&text).ok()
}

fn acc_of(rows: &[Json], needle: &str) -> Option<f64> {
    rows.iter()
        .find(|r| {
            r.get("method")
                .ok()
                .and_then(|m| m.as_str().ok().map(|s| s.contains(needle)))
                .unwrap_or(false)
        })
        .and_then(|r| r.get("quant_acc").ok().and_then(|v| v.as_f64().ok()))
}

fn check_table(exp: &str, claims: &mut Vec<Claim>, pairs: &[(&str, &str, &str)]) {
    match load(exp) {
        None => println!("{exp}: no cached result (run `cargo run --release -- exp {exp}`)"),
        Some(j) => {
            let rows = j.get("rows").unwrap().as_arr().unwrap().to_vec();
            println!("{exp}: {} cached rows", rows.len());
            for (hi, lo, what) in pairs {
                match (acc_of(&rows, hi), acc_of(&rows, lo)) {
                    (Some(a), Some(b)) => claims.push(Claim {
                        desc: format!("{exp}: {what}: {:.2}% vs {:.2}%", 100.0 * a, 100.0 * b),
                        ok: a >= b - 0.005, // half-point tolerance for run noise
                    }),
                    _ => println!("  {exp}: rows for {what} not found"),
                }
            }
        }
    }
}

fn main() {
    let mut claims = Vec::new();

    // Table 2 (ResNet18-S): ours@3bit >= uniform-3, ours >= random, ours >= hessian.
    check_table(
        "table2",
        &mut claims,
        &[
            ("Ours @3-bit", "Uniform 3W3A", "ours beats uniform at 3-bit level"),
            ("Ours @3-bit", "Random MP", "ours beats random at matched BitOps"),
            ("Ours @3-bit", "HAWQ-style", "ours >= Hessian criterion"),
            ("Ours @4-bit", "Uniform 4W4A", "ours beats uniform at 4-bit level"),
        ],
    );
    // Table 3 (ResNet50-S): ours >= hessian at matched compression.
    check_table(
        "table3",
        &mut claims,
        &[("Ours @12.2x", "HAWQ-style @12.2x", "ours >= HAWQ at 12.2x compression")],
    );
    // Table 4 (MobileNetV1-S).
    check_table(
        "table4",
        &mut claims,
        &[
            ("Ours @3-bit", "Uniform 3W3A", "ours beats uniform (3-bit)"),
            ("Ours @4-bit", "Uniform 4W4A", "ours beats uniform (4-bit)"),
        ],
    );
    // Table 5 weight-only.
    check_table(
        "table5",
        &mut claims,
        &[
            ("Ours 3MP", "Uniform W3A8", "weight-only ours beats uniform W3"),
            ("Ours 4MP", "Uniform W4A8", "weight-only ours beats uniform W4"),
        ],
    );
    // Table 6 ablation: ours@4 > reversed@4 (the 6.59% headline's shape).
    check_table(
        "table6",
        &mut claims,
        &[("Ours @4-bit", "Ours-R", "reversed assignment loses (Table 6)")],
    );

    // Efficiency JSON: speedup > 100x claim.
    if let Some(j) = load("efficiency") {
        let sp = j.get("speedup_1dev").unwrap().as_f64().unwrap();
        claims.push(Claim { desc: format!("efficiency: 1-device speedup {sp:.0}x (paper ~330x)"), ok: sp > 100.0 });
        let ilp = j.get("t_ilp_s").unwrap().as_f64().unwrap();
        claims.push(Claim { desc: format!("efficiency: ILP {ilp:.4}s (paper 0.06-0.35s)"), ok: ilp < 1.0 });
    } else {
        println!("efficiency: no cached result");
    }

    println!();
    let mut fails = 0;
    for c in &claims {
        println!("{} {}", if c.ok { "SHAPE-OK " } else { "SHAPE-FAIL" }, c.desc);
        if !c.ok {
            fails += 1;
        }
    }
    println!("\n{}/{} paper-shape claims hold on cached results", claims.len() - fails, claims.len());
}
