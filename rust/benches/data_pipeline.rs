//! Data-substrate benchmarks: synthetic generation throughput and the
//! batcher hot loop (which must never allocate per batch).
//!
//! Run: cargo bench --bench data_pipeline

use limpq::data::batcher::{Batcher, EvalBatches};
use limpq::data::{generate, SynthConfig};
use limpq::util::bench::{black_box, Bench};

fn main() {
    let bench = Bench::default();

    bench.run("generate_1000_imgs_16x16", || {
        black_box(generate(&SynthConfig { n: 1000, ..Default::default() }, 0))
    });

    let data = generate(&SynthConfig { n: 8000, ..Default::default() }, 0);

    let mut b64 = Batcher::new(&data, 64, 1);
    bench.run("batcher_next_64", || {
        let (x, y) = b64.next_batch();
        black_box((x[0], y[0]))
    });

    let mut b256 = Batcher::new(&data, 256, 1);
    bench.run("batcher_next_256", || {
        let (x, y) = b256.next_batch();
        black_box((x[0], y[0]))
    });

    bench.run("eval_batches_full_pass_250", || {
        let mut eb = EvalBatches::new(&data, 250);
        let mut acc = 0.0f32;
        while let Some((x, _)) = eb.next() {
            acc += x[0];
        }
        black_box(acc)
    });

    // Throughput summary: images/s through the training batcher.
    let stats = bench.run("batcher_epoch_8000", || {
        let mut b = Batcher::new(&data, 64, 2);
        for _ in 0..b.batches_per_epoch() {
            black_box(b.next_batch().1[0]);
        }
    });
    let imgs_per_s = 8000.0 / stats.mean.as_secs_f64();
    println!("batcher throughput: {imgs_per_s:.0} images/s (single thread)");
}
