//! Data-substrate benchmarks: synthetic generation throughput and the
//! batcher hot loop (which must never allocate per batch).
//!
//! Run: cargo bench --bench data_pipeline

use limpq::data::batcher::{Batcher, EvalBatches};
use limpq::data::{generate, SynthConfig};
use limpq::kernels::with_thread_scratch;
use limpq::util::bench::{black_box, Bench};

fn main() {
    let bench = if std::env::var("BENCH_QUICK").is_ok() { Bench::quick() } else { Bench::default() };

    bench.run("generate_1000_imgs_16x16", || {
        black_box(generate(&SynthConfig { n: 1000, ..Default::default() }, 0))
    });

    let data = generate(&SynthConfig { n: 8000, ..Default::default() }, 0);

    let mut b64 = Batcher::new(&data, 64, 1);
    bench.run("batcher_next_64", || {
        let (x, y) = b64.next_batch();
        black_box((x[0], y[0]))
    });

    let mut b256 = Batcher::new(&data, 256, 1);
    bench.run("batcher_next_256", || {
        let (x, y) = b256.next_batch();
        black_box((x[0], y[0]))
    });

    bench.run("eval_batches_full_pass_250", || {
        let mut eb = EvalBatches::new(&data, 250);
        let mut acc = 0.0f32;
        while let Some((x, _)) = eb.next() {
            acc += x[0];
        }
        black_box(acc)
    });

    // Owned-buffer batch draws (the joint trainer's pre-draw path): must
    // stay allocation-free at steady state.
    let mut b_into = Batcher::new(&data, 64, 1);
    let mut xbuf = Vec::new();
    let mut ybuf = Vec::new();
    bench.run("batcher_next_into_64", || {
        b_into.next_batch_into(&mut xbuf, &mut ybuf);
        black_box((xbuf[0], ybuf[0]))
    });

    // Scratch-arena checkout/return round trip (the forward hot path's
    // allocation amortizer).
    bench.run("scratch_take_put_16k", || {
        with_thread_scratch(|s| {
            let v = s.take_f32(16 * 1024);
            let first = v[0];
            s.put_f32(v);
            black_box(first)
        })
    });

    // Throughput summary: images/s through the training batcher.
    let stats = bench.run("batcher_epoch_8000", || {
        let mut b = Batcher::new(&data, 64, 2);
        for _ in 0..b.batches_per_epoch() {
            black_box(b.next_batch().1[0]);
        }
    });
    let imgs_per_s = 8000.0 / stats.mean.as_secs_f64();
    println!("batcher throughput: {imgs_per_s:.0} images/s (single thread)");
}
