//! Micro-benchmarks for the from-scratch ILP stack: branch-and-bound vs
//! MCKP dynamic program vs simplex relaxation, at paper-sized and larger
//! instances, plus the PolicyEngine front-end cold vs cached (the
//! memoized fleet-query path).  The paper's headline is "ResNet18 search
//! in 0.06 s on an M1" — these benches show where our solver stands on
//! this testbed.
//!
//! Run: cargo bench --bench ilp_micro

use limpq::engine::{PolicyEngine, SearchRequest, SolveBudget, SolverPref};
use limpq::importance::IndicatorStore;
use limpq::models::ModelMeta;
use limpq::quant::cost::uniform_bitops;
use limpq::search::mckp::{solve_dp, Resource};
use limpq::search::{bb::solve_bb, LayerOption, MpqProblem};
use limpq::util::bench::Bench;
use limpq::util::rng::Rng;

fn instance(layers: usize, opts: &[(u8, u8)], seed: u64, tightness: f64) -> MpqProblem {
    let mut rng = Rng::new(seed);
    let mut p = MpqProblem::default();
    for _ in 0..layers {
        let macs = 1_000_000 + rng.below(40_000_000) as u64;
        let numel = 1_000 + rng.below(500_000) as u64;
        let mut lo = Vec::new();
        for &(wb, ab) in opts {
            lo.push(LayerOption {
                w_bits: wb,
                a_bits: ab,
                cost: rng.uniform(0.0, 1.0) / (wb as f64 * ab as f64).sqrt(),
                bitops: macs * wb as u64 * ab as u64,
                size_bits: numel * wb as u64,
            });
        }
        p.groups.push(lo);
    }
    let max: u64 = p.groups.iter().map(|o| o.iter().map(|x| x.bitops).max().unwrap()).sum();
    let min: u64 = p.groups.iter().map(|o| o.iter().map(|x| x.bitops).min().unwrap()).sum();
    p.bitops_cap = Some(min + ((max - min) as f64 * tightness) as u64);
    p
}

fn all_pairs() -> Vec<(u8, u8)> {
    let mut v = Vec::new();
    for &w in &[2u8, 3, 4, 5, 6] {
        for &a in &[2u8, 3, 4, 5, 6] {
            v.push((w, a));
        }
    }
    v
}

/// ResNet18-shaped synthetic model meta (21 quantized layers) for the
/// engine front-end benches, which need a real `ModelMeta`.
fn synthetic_meta(layers: usize) -> ModelMeta {
    let mut rng = Rng::new(17);
    limpq::models::synthetic_meta(layers, move |_| 1_000_000 + rng.below(30_000_000) as u64)
}

fn main() {
    let bench = Bench::default();
    let pairs = all_pairs();

    // Paper-sized: ResNet18 (~21 layers, 25 combos)
    let p18 = instance(21, &pairs, 1, 0.4);
    bench.run("bb_resnet18_sized(21L x 25opt)", || solve_bb(&p18, 10_000_000).unwrap());

    // ResNet50-sized (~53 layers in the real paper)
    let p50 = instance(53, &pairs, 2, 0.4);
    bench.run("bb_resnet50_sized(53L x 25opt)", || solve_bb(&p50, 10_000_000).unwrap());

    // A much deeper hypothetical network
    let p200 = instance(200, &pairs, 3, 0.4);
    bench.run("bb_deep(200L x 25opt)", || solve_bb(&p200, 10_000_000).unwrap());

    // DP on a 4k grid vs BB at ResNet50 size
    bench.run("dp4096_resnet50_sized", || solve_dp(&p50, Resource::BitOps, 4096).unwrap());
    bench.run("dp16384_resnet50_sized", || solve_dp(&p50, Resource::BitOps, 16384).unwrap());

    // Two-constraint instance (Table 3 shape)
    let mut p2c = instance(30, &pairs, 4, 0.5);
    let smax: u64 = p2c.groups.iter().map(|o| o.iter().map(|x| x.size_bits).max().unwrap()).sum();
    p2c.size_cap_bits = Some(smax / 2);
    bench.run("bb_two_constraint(30L)", || solve_bb(&p2c, 10_000_000).unwrap());

    // Tightness sweep at fixed size: constraint hardness profile.
    for t in [0.15, 0.5, 0.85] {
        let p = instance(30, &pairs, 5, t);
        bench.run(&format!("bb_tightness_{t}"), || solve_bb(&p, 10_000_000).unwrap());
    }

    // Solution-quality cross-check printed alongside timing.
    let opt = solve_bb(&p50, 10_000_000).unwrap();
    let dp = solve_dp(&p50, Resource::BitOps, 16384).unwrap();
    println!(
        "quality: bb cost {:.6}, dp16384 cost {:.6} (gap {:+.3}%)",
        opt.cost,
        dp.cost,
        100.0 * (dp.cost - opt.cost) / opt.cost.abs().max(1e-12)
    );

    // ------------------------------------------------------------------
    // PolicyEngine front-end: cold solve vs memoized repeat of the same
    // fleet query — the serving-path win the LRU policy cache buys.
    // ------------------------------------------------------------------
    let meta = synthetic_meta(21);
    let imp = IndicatorStore::init_uniform(&meta).importance(&meta);
    let engine = PolicyEngine::new(meta.clone(), imp);
    let cap = uniform_bitops(&meta, 4, 4);
    let req = SearchRequest::builder().alpha(3.0).bitops_cap(cap).build().unwrap();

    let cold = bench.run("engine_cold(21L, bb via registry)", || {
        engine.solve_uncached(&req).unwrap()
    });
    engine.solve(&req).unwrap(); // warm the cache
    let cached = bench.run("engine_cached(identical request)", || {
        let resp = engine.solve(&req).unwrap();
        assert!(resp.cache_hit);
        resp
    });
    println!(
        "memoization: cold mean {:?} vs cached mean {:?} ({}x)",
        cold.mean,
        cached.mean,
        (cold.mean.as_nanos().max(1) / cached.mean.as_nanos().max(1))
    );

    // Raw-problem path through the registry (what exp/hessian flows use).
    let (sol, stats) = limpq::engine::solve_problem(
        &p18,
        &SolverPref::Auto,
        &SolveBudget { node_limit: 10_000_000, ..SolveBudget::default() },
    )
    .unwrap();
    println!(
        "registry auto on p18: solver={} nodes={} gap={:?} cost={:.6}",
        stats.solver, stats.nodes, stats.bound_gap, sol.cost
    );
}
