//! Runtime dispatch benchmarks: per-call cost of each AOT entry point
//! through the PJRT CPU client (the L3 hot path), plus the host-side
//! literal-conversion overhead in isolation.
//!
//! Run: make artifacts && cargo bench --bench runtime_exec

use std::path::Path;

use limpq::data::{generate, SynthConfig};
use limpq::importance::IndicatorStore;
use limpq::quant::BitConfig;
use limpq::runtime::pjrt::{lit_f32, PjrtBackend};
use limpq::runtime::ModelBackend;
use limpq::util::bench::{black_box, Bench};
use limpq::util::rng::Rng;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let bench = Bench::default();

    // Host-side literal conversion overhead (no execution).
    let buf = vec![0.5f32; 64 * 16 * 16 * 3];
    bench.run("lit_f32_convert(49k elems)", || lit_f32(&buf, &[64, 16, 16, 3]).unwrap());

    for model in ["mlp", "resnet18s", "mobilenetv1s", "resnet50s"] {
        let backend = PjrtBackend::load(dir, model).unwrap();
        let meta = backend.meta.clone();
        let mut rng = Rng::new(3);
        let flat = meta.init_params(&mut rng);
        let store = IndicatorStore::init_stats(&meta, &flat);
        let policy = BitConfig::uniform_pinned(&meta, 4, 4);
        let (sw, sa) = store.gather(&policy).unwrap();
        let (qw, qa) = policy.qmax_vectors();
        let tb = backend.train_batch();
        let eb = backend.eval_batch();
        let data = generate(&SynthConfig { n: eb.max(tb), ..Default::default() }, 0);
        let e = data.image_elems();

        let quick = limpq::util::bench::Bench {
            budget: std::time::Duration::from_secs(4),
            warmup: std::time::Duration::from_millis(600),
            max_iters: 50,
        };
        quick.run(&format!("{model}_train_step(B={tb})"), || {
            black_box(
                backend
                    .train_step(&flat, &sw, &sa, &qw, &qa, &data.images[..tb * e], &data.labels[..tb])
                    .unwrap(),
            )
        });
        quick.run(&format!("{model}_eval(B={eb})"), || {
            black_box(
                backend
                    .eval_step(&flat, &sw, &sa, &qw, &qa, &data.images[..eb * e], &data.labels[..eb])
                    .unwrap(),
            )
        });
        quick.run(&format!("{model}_fp_train_step(B={tb})"), || {
            black_box(backend.fp_train_step(&flat, &data.images[..tb * e], &data.labels[..tb]).unwrap())
        });
        let sb = meta.serve_batch;
        quick.run(&format!("{model}_logits(B={sb})"), || {
            black_box(backend.logits(&flat, &sw, &sa, &qw, &qa, &data.images[..sb * e]).unwrap())
        });
    }
    let _ = bench;
}
