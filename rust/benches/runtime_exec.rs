//! Runtime dispatch benchmarks.
//!
//! Three tiers, the first two artifact-free (they always run):
//!
//! 1. **Kernel-level**: packed/blocked GEMM (f32 and the i64-accumulating
//!    integer path) against the pre-PR naive strided loops, single- and
//!    multi-threaded — the >= 4x packed-vs-naive int-GEMM speedup
//!    criterion is read off these lines.  When a vector ISA is detected,
//!    forced `gemm_f32_simd` / `gemm_i8_simd` tiers run against forced
//!    `gemm_*_scalar` baselines so the SIMD speedup (>= 1.5x on the i8
//!    path on AVX2) is readable from one artifact.
//! 2. **End-to-end joint training**: wall-clock per atomic operation
//!    (the n+1 concurrent passes) on the analytic mock backend at 1
//!    thread vs all cores.
//! 3. **PJRT entry points** (needs `make artifacts`): per-call cost of
//!    each AOT entry point, as before.
//!
//! Run: cargo bench --bench runtime_exec [-- --json BENCH_kernels.json]
//!
//! `--json PATH` writes the kernel records as machine-readable JSON
//! (op, size, threads, ns/iter, throughput) — `tools/bench.sh` uses it to
//! track the perf trajectory across PRs.  Set `BENCH_QUICK=1` for the CI
//! smoke run (shorter budgets).

use std::path::Path;

use limpq::config::IndicatorCfg;
use limpq::data::batcher::Batcher;
use limpq::data::{generate, SynthConfig};
use limpq::importance::{IndicatorStore, JointTrainer};
use limpq::kernels::gemm::{
    gemm_f32, gemm_f32_naive, gemm_f32_with, gemm_i64, gemm_i64_naive, gemm_i8, gemm_i8_with,
    PackedF32, PackedI32, PackedI8,
};
use limpq::kernels::{simd, SimdBackend, WorkerPool};
use limpq::models::synthetic_meta;
use limpq::quant::BitConfig;
use limpq::runtime::mock::MockBackend;
use limpq::runtime::pjrt::{lit_f32, PjrtBackend};
use limpq::runtime::ModelBackend;
use limpq::util::bench::{black_box, json_out_arg, json_record, Bench, BenchStats};
use limpq::util::json::Json;
use limpq::util::rng::Rng;

/// One machine-readable bench record for BENCH_kernels.json (shared
/// schema from `util::bench`; GEMM records count MACs as the items).
fn record(op: &str, size: &str, threads: usize, stats: &BenchStats, ops_per_iter: f64) -> Json {
    json_record(op, size, threads, stats, ops_per_iter)
}

fn gemm_benches(bench: &Bench, records: &mut Vec<Json>) {
    let n_threads = WorkerPool::global().threads();
    for &(batch, in_f, out_f) in &[(8usize, 256usize, 256usize), (32, 512, 512)] {
        let size = format!("{batch}x{in_f}x{out_f}");
        let macs = (batch * in_f * out_f) as f64;
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..batch * in_f).map(|_| rng.normal_f32()).collect();
        let w: Vec<f32> = (0..in_f * out_f).map(|_| rng.normal_f32()).collect();
        let codes: Vec<i64> = (0..batch * in_f).map(|_| (rng.below(255) as i64) - 127).collect();
        let wq: Vec<i32> = (0..in_f * out_f).map(|_| (rng.below(255) as i32) - 127).collect();
        let pw = PackedF32::from_row_major(&w, in_f, out_f);
        let pq = PackedI32::from_row_major(&wq, in_f, out_f);
        let mut y = vec![0.0f32; batch * out_f];
        let mut acc = vec![0i64; batch * out_f];
        let one = WorkerPool::new(1);
        let all = WorkerPool::global();

        let s_naive_f = bench.run(&format!("gemm_f32_naive_{size}"), || {
            gemm_f32_naive(&x, batch, &w, in_f, out_f, &mut y);
            black_box(y[0])
        });
        records.push(record("gemm_f32_naive", &size, 1, &s_naive_f, macs));
        let s_packed_f = bench.run(&format!("gemm_f32_packed_{size}_t1"), || {
            gemm_f32(&x, batch, &pw, &mut y, &one);
            black_box(y[0])
        });
        records.push(record("gemm_f32_packed", &size, 1, &s_packed_f, macs));
        let s_packed_f_mt = bench.run(&format!("gemm_f32_packed_{size}_t{n_threads}"), || {
            gemm_f32(&x, batch, &pw, &mut y, &all);
            black_box(y[0])
        });
        records.push(record("gemm_f32_packed", &size, n_threads, &s_packed_f_mt, macs));

        let s_naive_i = bench.run(&format!("int_gemm_naive_{size}"), || {
            gemm_i64_naive(&codes, batch, &wq, in_f, out_f, &mut acc);
            black_box(acc[0])
        });
        records.push(record("int_gemm_naive", &size, 1, &s_naive_i, macs));
        let s_packed_i = bench.run(&format!("int_gemm_packed_{size}_t1"), || {
            gemm_i64(&codes, batch, &pq, &mut acc, &one);
            black_box(acc[0])
        });
        records.push(record("int_gemm_packed", &size, 1, &s_packed_i, macs));
        let s_packed_i_mt = bench.run(&format!("int_gemm_packed_{size}_t{n_threads}"), || {
            gemm_i64(&codes, batch, &pq, &mut acc, &all);
            black_box(acc[0])
        });
        records.push(record("int_gemm_packed", &size, n_threads, &s_packed_i_mt, macs));

        // i8-narrowed weight stream (4x cache density, same i64 math).
        let p8 = PackedI8::from_row_major(&wq, in_f, out_f);
        let s_packed_i8 = bench.run(&format!("int_gemm_packed_i8_{size}_t1"), || {
            gemm_i8(&codes, batch, &p8, &mut acc, &one);
            black_box(acc[0])
        });
        records.push(record("int_gemm_packed_i8", &size, 1, &s_packed_i8, macs));
        let s_packed_i8_mt = bench.run(&format!("int_gemm_packed_i8_{size}_t{n_threads}"), || {
            gemm_i8(&codes, batch, &p8, &mut acc, &all);
            black_box(acc[0])
        });
        records.push(record("int_gemm_packed_i8", &size, n_threads, &s_packed_i8_mt, macs));

        println!(
            "kernel speedup {size}: f32 packed/naive {:.2}x (1 thread), int packed/naive {:.2}x (1 thread), int packed x{n_threads} threads {:.2}x",
            s_naive_f.mean.as_secs_f64() / s_packed_f.mean.as_secs_f64(),
            s_naive_i.mean.as_secs_f64() / s_packed_i.mean.as_secs_f64(),
            s_naive_i.mean.as_secs_f64() / s_packed_i_mt.mean.as_secs_f64(),
        );

        // SIMD-vs-scalar tiers: force both paths explicitly so the >=
        // 1.5x i8 speedup criterion is readable from a single artifact
        // regardless of what `--simd` the session picked.  The forcing
        // is carried in the op name (the record's "simd" field stamps
        // the *session* backend, not the forced one).
        let detected = simd::detect();
        if detected == SimdBackend::Scalar {
            println!("SKIP gemm_*_simd tiers: no vector ISA detected on this host");
        } else {
            let s_f32_scalar = bench.run(&format!("gemm_f32_scalar_{size}_t1"), || {
                gemm_f32_with(&x, batch, &pw, &mut y, &one, SimdBackend::Scalar);
                black_box(y[0])
            });
            records.push(record("gemm_f32_scalar", &size, 1, &s_f32_scalar, macs));
            let s_f32_simd = bench.run(&format!("gemm_f32_simd_{size}_t1"), || {
                gemm_f32_with(&x, batch, &pw, &mut y, &one, detected);
                black_box(y[0])
            });
            records.push(record("gemm_f32_simd", &size, 1, &s_f32_simd, macs));
            let s_f32_simd_mt = bench.run(&format!("gemm_f32_simd_{size}_t{n_threads}"), || {
                gemm_f32_with(&x, batch, &pw, &mut y, &all, detected);
                black_box(y[0])
            });
            records.push(record("gemm_f32_simd", &size, n_threads, &s_f32_simd_mt, macs));

            let s_i8_scalar = bench.run(&format!("gemm_i8_scalar_{size}_t1"), || {
                gemm_i8_with(&codes, batch, &p8, &mut acc, &one, SimdBackend::Scalar);
                black_box(acc[0])
            });
            records.push(record("gemm_i8_scalar", &size, 1, &s_i8_scalar, macs));
            let s_i8_simd = bench.run(&format!("gemm_i8_simd_{size}_t1"), || {
                gemm_i8_with(&codes, batch, &p8, &mut acc, &one, detected);
                black_box(acc[0])
            });
            records.push(record("gemm_i8_simd", &size, 1, &s_i8_simd, macs));
            let s_i8_simd_mt = bench.run(&format!("gemm_i8_simd_{size}_t{n_threads}"), || {
                gemm_i8_with(&codes, batch, &p8, &mut acc, &all, detected);
                black_box(acc[0])
            });
            records.push(record("gemm_i8_simd", &size, n_threads, &s_i8_simd_mt, macs));

            println!(
                "simd speedup {size} ({}): f32 {:.2}x, i8 {:.2}x (1 thread, forced vs forced-scalar)",
                detected.name(),
                s_f32_scalar.mean.as_secs_f64() / s_f32_simd.mean.as_secs_f64(),
                s_i8_scalar.mean.as_secs_f64() / s_i8_simd.mean.as_secs_f64(),
            );
        }
    }
}

fn joint_training_benches(bench: &Bench, records: &mut Vec<Json>) {
    // Mock backend sized so one pass does real work (~120k-param grads).
    let layers = 6;
    let param_size = 120_000;
    let meta = synthetic_meta(layers, |i| 1000 * (i as u64 + 1));
    let backend = MockBackend::new(layers, param_size);
    let data = generate(&SynthConfig { n: 64, h: 2, w: 2, n_classes: 4, ..Default::default() }, 0);
    let flat = vec![0.01f32; param_size];
    let steps = 8;
    let cfg = IndicatorCfg { steps, lr: 0.05, weight_lr: 0.1, stats_init: true, ema: 0.9 };
    let n_threads = WorkerPool::global().threads();

    let mut run_at = |threads: usize, label: &str| -> BenchStats {
        let stats = bench.run(label, || {
            let mut batcher = Batcher::new(&data, 4, 3);
            let mut tr = JointTrainer::new(&backend, &meta, cfg.clone(), Rng::new(7));
            tr.pool = WorkerPool::new(threads);
            black_box(tr.train(&flat, &mut batcher).unwrap().store.sw[0][0])
        });
        records.push(record(
            "joint_train_atomic_op",
            &format!("{layers}L_{param_size}p"),
            threads,
            &stats,
            steps as f64, // atomic ops per iteration
        ));
        stats
    };
    let seq = run_at(1, "joint_train_8steps_t1");
    let par = run_at(n_threads, &format!("joint_train_8steps_t{n_threads}"));
    println!(
        "joint training: {:.2}ms/atomic-op sequential, {:.2}ms/atomic-op at {n_threads} threads ({:.2}x, bit-identical indicators)",
        seq.mean.as_secs_f64() * 1e3 / steps as f64,
        par.mean.as_secs_f64() * 1e3 / steps as f64,
        seq.mean.as_secs_f64() / par.mean.as_secs_f64(),
    );
}

fn main() {
    let json_path = json_out_arg();
    let quick_mode = std::env::var("BENCH_QUICK").is_ok();
    let bench = if quick_mode { Bench::quick() } else { Bench::default() };

    let mut records: Vec<Json> = Vec::new();
    gemm_benches(&bench, &mut records);
    joint_training_benches(&bench, &mut records);

    if let Some(path) = &json_path {
        std::fs::write(path, Json::Arr(records).to_string()).expect("write bench json");
        println!("kernel bench records -> {path}");
    }

    // ---- PJRT entry points (artifact-gated, unchanged) ----
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP pjrt tier: artifacts not built (run `make artifacts`)");
        return;
    }

    // Host-side literal conversion overhead (no execution).
    let buf = vec![0.5f32; 64 * 16 * 16 * 3];
    bench.run("lit_f32_convert(49k elems)", || lit_f32(&buf, &[64, 16, 16, 3]).unwrap());

    for model in ["mlp", "resnet18s", "mobilenetv1s", "resnet50s"] {
        let backend = PjrtBackend::load(dir, model).unwrap();
        let meta = backend.meta.clone();
        let mut rng = Rng::new(3);
        let flat = meta.init_params(&mut rng);
        let store = IndicatorStore::init_stats(&meta, &flat);
        let policy = BitConfig::uniform_pinned(&meta, 4, 4);
        let (sw, sa) = store.gather(&policy).unwrap();
        let (qw, qa) = policy.qmax_vectors();
        let tb = backend.train_batch();
        let eb = backend.eval_batch();
        let data = generate(&SynthConfig { n: eb.max(tb), ..Default::default() }, 0);
        let e = data.image_elems();

        let quick = limpq::util::bench::Bench {
            budget: std::time::Duration::from_secs(4),
            warmup: std::time::Duration::from_millis(600),
            max_iters: 50,
        };
        quick.run(&format!("{model}_train_step(B={tb})"), || {
            black_box(
                backend
                    .train_step(&flat, &sw, &sa, &qw, &qa, &data.images[..tb * e], &data.labels[..tb])
                    .unwrap(),
            )
        });
        quick.run(&format!("{model}_eval(B={eb})"), || {
            black_box(
                backend
                    .eval_step(&flat, &sw, &sa, &qw, &qa, &data.images[..eb * e], &data.labels[..eb])
                    .unwrap(),
            )
        });
        quick.run(&format!("{model}_fp_train_step(B={tb})"), || {
            black_box(backend.fp_train_step(&flat, &data.images[..tb * e], &data.labels[..tb]).unwrap())
        });
        let sb = meta.serve_batch;
        quick.run(&format!("{model}_logits(B={sb})"), || {
            black_box(backend.logits(&flat, &sw, &sa, &qw, &qa, &data.images[..sb * e]).unwrap())
        });
    }
}
