//! Offline stand-in for the `xla` (xla_extension) Rust bindings.
//!
//! The build environment carries neither the crate nor the native
//! `libxla_extension` runtime, so this stub keeps the crate surface that
//! `limpq::runtime::pjrt` compiles against:
//!
//! * [`Literal`] is FULLY functional host-side (typed storage, shape,
//!   `vec1`/`reshape`/`to_vec`/`to_tuple`/`element_count`) — the literal
//!   helpers and their unit tests behave exactly like the real crate;
//! * the PJRT pieces ([`PjRtClient`], [`XlaComputation`],
//!   [`HloModuleProto`], [`PjRtLoadedExecutable`]) parse/carry their
//!   inputs but fail at `PjRtClient::cpu()` / `compile` time with a
//!   clear "runtime unavailable" error.
//!
//! Every caller already degrades gracefully: the PJRT test tier and the
//! experiment drivers skip or error out with context when artifacts /
//! the runtime are missing, while the mock-backend tier (the tier-1
//! suite) never touches this crate's execution path.

use std::fmt;

/// Stub error type, mirroring `xla::Error` closely enough for `?` and
/// `context(..)` conversions (it implements `std::error::Error`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

const UNAVAILABLE: &str = "xla runtime unavailable: this build vendors the offline xla stub \
     (no libxla_extension in the container); PJRT execution requires the real bindings";

// ---------------------------------------------------------------------------
// Literal: fully functional host-side
// ---------------------------------------------------------------------------

/// Element types the stub stores natively.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> LiteralData;
    fn unwrap(data: &LiteralData) -> Option<&[Self]>;
}

#[derive(Debug, Clone)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> LiteralData {
        LiteralData::F32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<&[f32]> {
        match data {
            LiteralData::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> LiteralData {
        LiteralData::I32(data)
    }
    fn unwrap(data: &LiteralData) -> Option<&[i32]> {
        match data {
            LiteralData::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// A host literal: typed flat storage plus dimensions.
#[derive(Debug, Clone)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let n = data.len() as i64;
        Literal { data: T::wrap(data.to_vec()), dims: vec![n] }
    }

    /// Tuple literal (what lowered `return_tuple=True` entry points emit).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { data: LiteralData::Tuple(parts), dims: vec![] }
    }

    /// Reshape; errs when the element count does not match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return err(format!(
                "reshape: {} elements cannot fill shape {dims:?} ({want})",
                have
            ));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(parts) => parts.iter().map(|p| p.element_count()).sum(),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("to_vec: element type mismatch".into()))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.data {
            LiteralData::Tuple(parts) => Ok(parts.clone()),
            _ => err("to_tuple: literal is not a tuple"),
        }
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

// ---------------------------------------------------------------------------
// HLO / PJRT surface: compile-compatible, runtime-unavailable
// ---------------------------------------------------------------------------

/// Parsed HLO module carrier.  The stub verifies the file exists and
/// carries its text; it cannot verify or execute the HLO itself.
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("read HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

pub struct XlaComputation {
    #[allow(dead_code)]
    text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { text: proto.text.clone() }
    }
}

/// PJRT client handle.  `cpu()` fails in the stub build.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        err(UNAVAILABLE)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        err(UNAVAILABLE)
    }
}

/// Device-side buffer handle returned by `execute`.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        err(UNAVAILABLE)
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.element_count(), 4);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn tuple_literals() {
        let t = Literal::tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32, 3])]);
        assert_eq!(t.element_count(), 3);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
    }

    #[test]
    fn pjrt_paths_fail_closed() {
        assert!(PjRtClient::cpu().is_err());
    }
}
