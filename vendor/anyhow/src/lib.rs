//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment carries no crates.io mirror, so this workspace
//! vendors the small slice of anyhow's API that limpq actually uses:
//!
//! * [`Error`] — an opaque, context-carrying error value (Send + Sync)
//! * [`Result<T>`] — `std::result::Result<T, Error>` with a default param
//! * [`anyhow!`], [`bail!`], [`ensure!`] — construction macros
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`
//!
//! Semantics mirror the real crate where limpq depends on them:
//! `{}` displays the outermost message only, `{:#}` joins the whole
//! context chain with `": "`, and any `std::error::Error` converts via
//! `?` (its source chain is captured).  `Error` deliberately does NOT
//! implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` impl coherent — the same trick the real
//! anyhow uses.

use std::fmt;

/// An error value: an outermost message plus the chain of underlying
/// causes, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The chain of messages, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full context chain, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failible computations (`Result` and `Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn display_and_alternate() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn io() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here/xyz")?;
            Ok(s)
        }
        assert!(io().is_err());
    }

    #[test]
    fn ensure_forms() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0);
            ensure!(x < 10, "too big: {x}");
            Ok(x)
        }
        assert!(check(5).is_ok());
        assert!(check(-1).unwrap_err().to_string().contains("condition failed"));
        assert_eq!(check(11).unwrap_err().to_string(), "too big: 11");
    }

    #[test]
    fn option_context() {
        let x: Option<i32> = None;
        let e = x.context("missing thing").unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
    }

    #[test]
    fn root_cause_and_chain() {
        let e = fails().context("mid").context("top").unwrap_err();
        assert_eq!(e.root_cause(), "inner 42");
        assert_eq!(e.chain().count(), 3);
    }
}
